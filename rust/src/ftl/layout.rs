//! On-flash page layouts (FP16) for the two KV orientations.

use crate::util::f16::{decode_slice, encode_slice, f16_bits_to_f32, f32_to_f16_bits};

/// Quantise one value through the FP16 boundary (what flash will hold).
#[inline]
pub fn q16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Encode token-major rows (n tokens x d channels) into page bytes.
pub fn encode_rows(rows: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(rows.len() * 2);
    encode_slice(rows, &mut out);
    out
}

/// Decode `count` f32 values from page bytes (token-major layout).
pub fn decode_rows(page: &[u8], count: usize) -> Vec<f32> {
    let mut v = decode_slice(&page[..count * 2]);
    v.truncate(count);
    v
}

/// Build an embedding-indexed page: channels [eg*m, (eg+1)*m) of K over
/// `t_emb` token rows, channel-major (`lane` = all tokens of one channel).
/// `rows` is token-major (t_emb x d).
pub fn encode_emb_page(rows: &[f32], d: usize, eg: usize, m: usize, t_emb: usize) -> Vec<u8> {
    debug_assert_eq!(rows.len(), t_emb * d);
    let mut lane_major = Vec::with_capacity(m * t_emb);
    for off in 0..m {
        let c = eg * m + off;
        for t in 0..t_emb {
            lane_major.push(rows[t * d + c]);
        }
    }
    encode_rows(&lane_major)
}

/// Extract one channel lane (t_emb token values) from an embedding page.
pub fn decode_emb_lane(page: &[u8], off: usize, t_emb: usize) -> Vec<f32> {
    let start = off * t_emb * 2;
    decode_slice(&page[start..start + t_emb * 2])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_rows_roundtrip() {
        let rows: Vec<f32> = (0..8 * 4).map(|i| i as f32 * 0.25).collect();
        let page = encode_rows(&rows);
        assert_eq!(page.len(), rows.len() * 2);
        assert_eq!(decode_rows(&page, rows.len()), rows); // values exact in f16
    }

    #[test]
    fn emb_page_lane_extraction() {
        let (d, m, t_emb) = (8usize, 4usize, 6usize);
        // rows[t*d + c] = t*100 + c, exactly representable
        let rows: Vec<f32> = (0..t_emb * d).map(|i| ((i / d) * 100 + i % d) as f32).collect();
        for eg in 0..d / m {
            let page = encode_emb_page(&rows, d, eg, m, t_emb);
            for off in 0..m {
                let lane = decode_emb_lane(&page, off, t_emb);
                let c = eg * m + off;
                for (t, &v) in lane.iter().enumerate() {
                    assert_eq!(v, (t * 100 + c) as f32);
                }
            }
        }
    }

    #[test]
    fn q16_idempotent() {
        for x in [0.1f32, -3.7, 1234.5, 1e-5] {
            assert_eq!(q16(q16(x)), q16(x));
        }
    }
}
