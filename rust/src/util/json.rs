//! Minimal JSON parser/writer for the artifact manifest and result dumps.
//!
//! The offline crate set has no `serde` facade, so this module implements
//! the subset of JSON we produce and consume: objects, arrays, strings
//! (with escapes), numbers, booleans, null.  Numbers are stored as f64;
//! the manifest never exceeds 2^53 so this is lossless for our use.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key — manifest
    /// lookups should fail loudly, not with `unwrap` panics.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy the full sequence verbatim
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = (start + len).min(self.b.len());
                    match std::str::from_utf8(&self.b[start..self.pos]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return Err(self.err("bad utf-8")),
                    }
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }
}

// ---- writer ---------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_usize(), Some(1));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parse_unicode_escape_and_utf8() {
        assert_eq!(
            Json::parse(r#""éx""#).unwrap(),
            Json::Str("\u{e9}x".into())
        );
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{'a':1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":true,"c":null,"d":{"e":-7}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn req_reports_key() {
        let v = Json::parse("{}").unwrap();
        let err = v.req("model").unwrap_err().to_string();
        assert!(err.contains("model"));
    }
}
