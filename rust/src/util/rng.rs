//! Deterministic PRNG (xoshiro256**) — no `rand` crate offline.
//!
//! Used by the workload generators, the property-test harness, and the
//! synthetic attention-statistics model.  Seeded explicitly everywhere so
//! every benchmark run is reproducible.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal sample from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let s = [
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
        ];
        Rng { s, spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (n > 0).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential with mean `mu` (arrival gaps, flash latency jitter).
    pub fn exp(&mut self, mu: f64) -> f64 {
        -mu * (1.0 - self.f64()).ln()
    }

    /// Zipf-like heavy-tail sample in [0, n): used by the attention
    /// statistics generator (a few tokens dominate attention mass).
    pub fn zipf(&mut self, n: usize, alpha: f64) -> usize {
        // inverse-cdf on a truncated pareto; cheap and good enough for
        // shaping score distributions
        let u = self.f64().max(1e-12);
        let x = (1.0 - u * (1.0 - (n as f64).powf(1.0 - alpha))).powf(1.0 / (1.0 - alpha));
        (x as usize).min(n - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Fresh child generator (for per-request / per-head streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.range(3, 7);
            assert!((3..=7).contains(&n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let mut r = Rng::new(5);
        let mut head = 0;
        let n = 20_000;
        for _ in 0..n {
            if r.zipf(1024, 1.2) < 16 {
                head += 1;
            }
        }
        // the first 16 of 1024 buckets should hold far more than 16/1024
        assert!(head as f64 / n as f64 > 0.3, "head={head}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..32).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..32).collect::<Vec<_>>());
        assert_ne!(v, (0..32).collect::<Vec<_>>());
    }
}
