//! IEEE 754 half-precision conversion (no `half` crate offline).
//!
//! The flash pages store KV tensors in FP16 exactly as the paper's CSD does
//! (§IV-C sizes all groups in FP16); the engine decodes to f32 for compute.
//! Round-to-nearest-even on encode, standard widening on decode.

/// f32 -> f16 bit pattern, round-to-nearest-even, IEEE semantics
/// (overflow -> inf, subnormal flush handled properly).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x7f_ffff;

    if exp == 0xff {
        // inf / nan
        let nan = if man != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | nan | ((man >> 13) as u16 & 0x3ff);
    }
    // unbiased exponent for f16
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        // subnormal or zero
        if e < -10 {
            return sign; // too small -> +-0
        }
        // add implicit bit, shift into subnormal position with rounding
        let man = man | 0x80_0000;
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = man + half - 1 + ((man >> shift) & 1);
        return sign | (rounded >> shift) as u16;
    }
    // normal: round mantissa from 23 to 10 bits, RNE
    let half = 0x0fff + ((man >> 13) & 1);
    let man_r = man + half;
    if man_r & 0x80_0000 != 0 {
        // mantissa overflow bumps exponent
        let e = e + 1;
        if e >= 0x1f {
            return sign | 0x7c00;
        }
        return sign | ((e as u16) << 10);
    }
    sign | ((e as u16) << 10) | ((man_r >> 13) as u16 & 0x3ff)
}

/// f16 bit pattern -> f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: value = man * 2^-24; normalise around the MSB
            let p = 31 - man.leading_zeros(); // MSB position, 0..=9
            let exp32 = 103 + p; // -24 + p + 127
            let man32 = (man << (23 - p)) & 0x7f_ffff;
            sign | (exp32 << 23) | man32
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Encode a f32 slice to packed little-endian f16 bytes.
pub fn encode_slice(xs: &[f32], out: &mut Vec<u8>) {
    out.reserve(xs.len() * 2);
    for &x in xs {
        out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
    }
}

/// Decode packed little-endian f16 bytes to f32.
pub fn decode_slice(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 2, 0);
    bytes
        .chunks_exact(2)
        .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exact_values_roundtrip() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 1.5, 0.099975586] {
            let h = f32_to_f16_bits(x);
            assert_eq!(f16_bits_to_f32(h), x, "x={x}");
        }
    }

    #[test]
    fn special_values() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // overflow saturates to inf
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e8)), f32::INFINITY);
        // tiny flushes to zero
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-12)), 0.0);
    }

    #[test]
    fn subnormals_roundtrip() {
        // smallest positive f16 subnormal = 2^-24
        let tiny = 2.0f32.powi(-24);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tiny)), tiny);
        let sub = 2.0f32.powi(-20);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(sub)), sub);
    }

    #[test]
    fn relative_error_bounded() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let x = (rng.normal() * 10.0) as f32;
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            let rel = ((x - y) / x.abs().max(1e-3)).abs();
            assert!(rel < 1e-3, "x={x} y={y}");
        }
    }

    #[test]
    fn rne_ties() {
        // 1 + 2^-11 is exactly between 1.0 and 1+2^-10: RNE rounds to even (1.0)
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), 1.0);
        // 1 + 3*2^-11 is a tie between mantissa 1 and 2: RNE picks even (2)
        let x = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), 1.0 + 2.0f32.powi(-9));
    }

    #[test]
    fn slice_roundtrip() {
        let xs: Vec<f32> = (0..257).map(|i| (i as f32 - 128.0) * 0.37).collect();
        let mut bytes = Vec::new();
        encode_slice(&xs, &mut bytes);
        assert_eq!(bytes.len(), xs.len() * 2);
        let back = decode_slice(&bytes);
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() * 1e-3 + 1e-4);
        }
    }
}
