//! Minimal property-testing harness (no `proptest` in the offline crate
//! set).  Each property runs `iters` cases from seeded generators; on
//! failure it reports the case index and seed so the case replays exactly.
//!
//! Usage (`no_run`: doctest binaries don't get the xla rpath link flag):
//! ```no_run
//! use instinfer::util::prop::check;
//! check("sum_commutes", 100, |rng| (rng.below(10), rng.below(10)),
//!       |&(a, b)| if a + b == b + a { Ok(()) } else { Err("!".into()) });
//! ```

use super::rng::Rng;

/// Run `prop` over `iters` generated cases; panics with a replayable seed
/// on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    iters: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    // fixed base seed: failures are deterministic across runs; vary cases
    // by iteration index
    for i in 0..iters {
        let seed = 0x5eed_0000 + i as u64;
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property {name:?} failed at case {i} (seed={seed:#x}):\n  \
                 case: {case:?}\n  error: {msg}"
            );
        }
    }
}

/// Like `check` but the property gets a fresh RNG too (for stochastic
/// assertions inside the property body).
pub fn check_rng<T: std::fmt::Debug>(
    name: &str,
    iters: usize,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T, &mut Rng) -> Result<(), String>,
) {
    for i in 0..iters {
        let seed = 0x5eed_1000 + i as u64;
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        let mut prng = rng.fork();
        if let Err(msg) = prop(&case, &mut prng) {
            panic!(
                "property {name:?} failed at case {i} (seed={seed:#x}):\n  \
                 case: {case:?}\n  error: {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add_comm", 50, |r| (r.below(100), r.below(100)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always_fails")]
    fn failing_property_panics_with_name() {
        check("always_fails", 5, |r| r.below(10), |_| Err("no".into()));
    }
}
