//! Streaming statistics (Welford) and percentile helpers for benches.

#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample (linear interpolation); `q` in [0, 100].
pub fn percentile(xs: &mut [f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (xs.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        xs[lo]
    } else {
        xs[lo] + (xs[hi] - xs[lo]) * (rank - lo as f64)
    }
}

/// Geometric mean of strictly positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
    }

    #[test]
    fn percentile_interpolates() {
        let mut xs = vec![10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&mut xs, 0.0), 10.0);
        assert_eq!(percentile(&mut xs, 100.0), 40.0);
        assert_eq!(percentile(&mut xs, 50.0), 25.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
