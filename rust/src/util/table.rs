//! Aligned ASCII table printer — the bench harness prints the same rows
//! the paper's figures plot (one table per figure).

#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// True when any row is an error row — the bench harness's shared
    /// convention puts the literal sentinel `ERR` in a data cell and the
    /// rendered error next to it.  `bench all` gates its exit code on
    /// this so a sweep that silently degraded to error rows fails CI.
    pub fn has_error_rows(&self) -> bool {
        self.rows.iter().any(|r| r.iter().any(|c| c == "ERR"))
    }

    /// JSON view (`{"title", "header", "rows"}`) for machine-readable
    /// bench output (`instinfer bench <target> --json FILE`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let strs = |xs: &[String]| Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect());
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("title".to_string(), Json::Str(self.title.clone()));
        obj.insert("header".to_string(), strs(&self.header));
        obj.insert(
            "rows".to_string(),
            Json::Arr(self.rows.iter().map(|r| strs(r)).collect()),
        );
        Json::Obj(obj)
    }
}

/// Format a float with engineering-style precision (3 significant-ish).
pub fn eng(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 {
        format!("{:.0}", x)
    } else if x.abs() >= 10.0 {
        format!("{:.1}", x)
    } else if x.abs() >= 0.01 {
        format!("{:.3}", x)
    } else {
        format!("{:.2e}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["bs", "tput"]);
        t.row(vec!["4".into(), "12.5".into()]);
        t.row(vec!["256".into(), "3.1".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[3].len(), lines[4].len());
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn json_roundtrips() {
        let mut t = Table::new("demo", &["bs", "tput"]);
        t.row(vec!["4".into(), "12.5".into()]);
        let j = t.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("title").unwrap().as_str(), Some("demo"));
        assert_eq!(parsed.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn error_rows_detected() {
        let mut t = Table::new("demo", &["bs", "tput"]);
        t.row(vec!["4".into(), "12.5".into()]);
        assert!(!t.has_error_rows());
        t.row(vec!["8".into(), "ERR".into()]);
        assert!(t.has_error_rows());
    }

    #[test]
    fn eng_formats() {
        assert_eq!(eng(0.0), "0");
        assert_eq!(eng(1234.0), "1234");
        assert_eq!(eng(12.34), "12.3");
        assert_eq!(eng(0.5), "0.500");
    }
}
