//! Small self-contained utilities (no external deps available offline):
//! a JSON parser/writer, a fast PRNG, statistics helpers, a table printer,
//! and a minimal property-testing harness.

pub mod f16;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
