//! GPU operator model: the per-operator FLOP/byte inventory of §III-B and
//! the roofline placement analysis behind Fig. 6.
//!
//! The functional plane executes the same operators for real through PJRT;
//! this module prices them at OPT-13B scale on the A6000 so the timing
//! plane can compose decode/prefill step times.

use crate::config::hw::{CsdSpec, GpuSpec};
use crate::config::model::{ModelShape, FP16_BYTES};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// One operator class of one layer at a given batch/context point.
#[derive(Debug, Clone)]
pub struct OpCost {
    pub name: &'static str,
    pub phase: Phase,
    /// FLOPs per layer for the whole batch
    pub flops: f64,
    /// bytes touched per layer (weights + activations + KV where relevant)
    pub bytes: f64,
}

impl OpCost {
    pub fn intensity(&self) -> f64 {
        self.flops / self.bytes.max(1.0)
    }

    pub fn gpu_time(&self, gpu: &GpuSpec) -> f64 {
        gpu.op_time(self.flops, self.bytes)
    }

    pub fn csd_time(&self, csd: &CsdSpec) -> f64 {
        csd.op_time(self.flops, self.bytes)
    }
}

/// Per-layer decode-step operators for batch `b` at context length `s`
/// (Fig. 6's decode points; the paper's Logit/Attend split kept).
pub fn decode_ops(m: &ModelShape, b: usize, s: usize) -> Vec<OpCost> {
    let d = m.d_model as f64;
    let f = m.d_ffn as f64;
    let bf = b as f64;
    let sf = s as f64;
    let hd = (m.n_heads * m.d_head) as f64;
    let w = FP16_BYTES as f64;
    vec![
        OpCost {
            name: "QKV Proj.",
            phase: Phase::Decode,
            flops: bf * 2.0 * 3.0 * d * d,
            bytes: 3.0 * d * d * w + bf * (d + 3.0 * d) * w,
        },
        OpCost {
            name: "Logit",
            phase: Phase::Decode,
            flops: bf * 2.0 * sf * hd,
            bytes: bf * (sf * hd + hd) * w, // K cache + q
        },
        OpCost {
            name: "Attend",
            phase: Phase::Decode,
            flops: bf * 2.0 * sf * hd,
            bytes: bf * (sf * hd + hd) * w, // V cache + out
        },
        OpCost {
            name: "O Proj.",
            phase: Phase::Decode,
            flops: bf * 2.0 * d * d,
            bytes: d * d * w + bf * 2.0 * d * w,
        },
        OpCost {
            name: "FFN",
            phase: Phase::Decode,
            flops: bf * 2.0 * 2.0 * d * f,
            bytes: 2.0 * d * f * w + bf * (2.0 * d + f) * w,
        },
    ]
}

/// Per-layer prefill operators for batch `b`, prompt length `s`.
pub fn prefill_ops(m: &ModelShape, b: usize, s: usize) -> Vec<OpCost> {
    let d = m.d_model as f64;
    let f = m.d_ffn as f64;
    let toks = (b * s) as f64;
    let hd = (m.n_heads * m.d_head) as f64;
    let w = FP16_BYTES as f64;
    let bf = b as f64;
    let sf = s as f64;
    vec![
        OpCost {
            name: "QKV Proj.",
            phase: Phase::Prefill,
            flops: toks * 2.0 * 3.0 * d * d,
            bytes: 3.0 * d * d * w + toks * 4.0 * d * w,
        },
        OpCost {
            name: "Logit",
            phase: Phase::Prefill,
            flops: bf * 2.0 * sf * sf * hd,
            bytes: bf * (2.0 * sf * hd + sf * sf * m.n_heads as f64) * w,
        },
        OpCost {
            name: "Attend",
            phase: Phase::Prefill,
            flops: bf * 2.0 * sf * sf * hd,
            bytes: bf * (2.0 * sf * hd + sf * sf * m.n_heads as f64) * w,
        },
        OpCost {
            name: "O Proj.",
            phase: Phase::Prefill,
            flops: toks * 2.0 * d * d,
            bytes: d * d * w + toks * 2.0 * d * w,
        },
        OpCost {
            name: "FFN",
            phase: Phase::Prefill,
            flops: toks * 2.0 * 2.0 * d * f,
            bytes: 2.0 * d * f * w + toks * (2.0 * d + f) * w,
        },
    ]
}

/// Whole-layer GPU decode time excluding attention (the part InstInfer
/// keeps on the GPU: QKV + O proj + FFN).
pub fn gpu_decode_nonattn_time(m: &ModelShape, gpu: &GpuSpec, b: usize) -> f64 {
    decode_ops(m, b, 1)
        .iter()
        .filter(|o| o.name != "Logit" && o.name != "Attend")
        .map(|o| o.gpu_time(gpu))
        .sum()
}

/// Whole-layer GPU decode attention time (dense, KV resident in VRAM).
pub fn gpu_decode_attn_time(m: &ModelShape, gpu: &GpuSpec, b: usize, s: usize) -> f64 {
    decode_ops(m, b, s)
        .iter()
        .filter(|o| o.name == "Logit" || o.name == "Attend")
        .map(|o| o.gpu_time(gpu))
        .sum()
}

/// Whole-layer GPU prefill time for the full prompt.
pub fn gpu_prefill_layer_time(m: &ModelShape, gpu: &GpuSpec, b: usize, s: usize) -> f64 {
    prefill_ops(m, b, s).iter().map(|o| o.gpu_time(gpu)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_placement_decisions() {
        // the roofline analysis of §III-B, quantified:
        let m = ModelShape::opt_13b();
        let gpu = GpuSpec::a6000();
        let csd = CsdSpec::zynq7045();

        // prefill ops are compute-intense: GPU >> CSD on every op
        for op in prefill_ops(&m, 8, 1024) {
            assert!(
                op.csd_time(&csd) > 20.0 * op.gpu_time(&gpu),
                "{}: csd {} gpu {}", op.name, op.csd_time(&csd), op.gpu_time(&gpu)
            );
        }

        // decode attention has intensity ~1: memory-bound on both
        let ops = decode_ops(&m, 64, 2048);
        let logit = ops.iter().find(|o| o.name == "Logit").unwrap();
        assert!(logit.intensity() < 2.0);
        // decode QKV/FFN at bs=64 are near/above the CSD's knee
        let ffn = ops.iter().find(|o| o.name == "FFN").unwrap();
        assert!(ffn.intensity() > csd.knee(), "FFN intensity {}", ffn.intensity());
    }

    #[test]
    fn decode_attention_scales_with_context() {
        let m = ModelShape::opt_13b();
        let gpu = GpuSpec::a6000();
        let t1 = gpu_decode_attn_time(&m, &gpu, 16, 512);
        let t2 = gpu_decode_attn_time(&m, &gpu, 16, 2048);
        assert!(t2 > 3.0 * t1 && t2 < 5.0 * t1);
    }

    #[test]
    fn prefill_dominated_by_projections() {
        let m = ModelShape::opt_13b();
        let ops = prefill_ops(&m, 8, 1024);
        let proj: f64 = ops.iter().filter(|o| o.name != "Logit" && o.name != "Attend")
            .map(|o| o.flops).sum();
        let attn: f64 = ops.iter().filter(|o| o.name == "Logit" || o.name == "Attend")
            .map(|o| o.flops).sum();
        assert!(proj > attn, "projection flops should dominate at s=1024");
    }
}
