//! Baseline system models: GPU-only, DeepSpeed-MII-like (host-DRAM
//! offload with kernel-swap cliff) and FlexGen-like (tiered / SSD offload
//! through the host filesystem), plus the FlexGen-SparQ variant.
//!
//! All reimplement the *dataflow* of the original systems on the shared
//! substrate (DESIGN.md §1): who holds the KV cache, which link each byte
//! crosses, and what gets buffered where.  Efficiency calibrations live in
//! [`crate::systems::stepmodel`].

use crate::config::model::FP16_BYTES;
use crate::config::system::SystemConfig;
use crate::gpu;
use crate::pcie::{self, Path};
use crate::systems::stepmodel::{
    check_vram, gpu_nonattn_step, integrate_decode, RunSummary, StepBreakdown,
    HOST_STAGE_EFF, SSD_FS_EFF, SWAP_BW,
};

/// Where a system keeps the KV cache for a given batch size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvTier {
    Vram,
    HostDram,
    /// (fraction resident in DRAM, remainder swapped/spilled to SSD)
    Ssd,
}

/// GPU-only reference: everything in VRAM (upper bound; OOMs early).
pub fn gpu_only(cfg: &SystemConfig, b: usize) -> Result<RunSummary, String> {
    let m = &cfg.model;
    let need = m.weight_bytes() + cfg.kv_bytes_total(b);
    if need > cfg.gpu.vram_bytes {
        return Err(format!(
            "OOM: weights+KV = {:.1} GB > VRAM",
            need as f64 / 1e9
        ));
    }
    let prefill = prefill_gpu_compute(cfg, b);
    let step = |s: usize| {
        let (w, c) = gpu_nonattn_step(cfg, b);
        let attn: f64 =
            m.n_layers as f64 * gpu::gpu_decode_attn_time(m, &cfg.gpu, b, s);
        StepBreakdown { weight: w, kv: attn, compute: c, comm: 0.0 }
    };
    finish(cfg, b, "GPU-only", prefill, step)
}

/// DeepSpeed-MII / ZeRO-Inference-like: KV pinned in host DRAM, streamed
/// to the GPU each step.  Once weights' pinned copy + KV exceed usable
/// DRAM the kernel swaps to SSD — the 97%/32.6x collapse of Figs. 4/12.
pub fn deepspeed(cfg: &SystemConfig, b: usize) -> Result<RunSummary, String> {
    let m = &cfg.model;
    check_vram(cfg, b, 2)?; // streams KV layer-by-layer: small buffer
    let host_need = m.weight_bytes() + cfg.kv_bytes_total(b);
    let usable = cfg.host.usable_dram();
    let swap_frac = if host_need > usable {
        ((host_need - usable) as f64 / cfg.kv_bytes_total(b) as f64).min(1.0)
    } else {
        0.0
    };

    let prefill = {
        let compute = prefill_gpu_compute(cfg, b);
        // KV written back to host DRAM over PCIe, partially overlapped
        let kv_bytes = m.kv_bytes(b, cfg.input_len) as f64;
        let ship = kv_bytes / (cfg.pcie.gpu_host_bw * HOST_STAGE_EFF);
        compute.max(ship) + 0.25 * compute.min(ship)
    };
    let step = move |s: usize| {
        let (w, c) = gpu_nonattn_step(cfg, b);
        let kv_bytes = m.kv_bytes(b, s) as f64;
        // scan-thrash: any overflow makes the sequential KV sweep fault on
        // (nearly) every page — LRU keeps exactly the wrong pages
        let kv = if swap_frac > 0.0 {
            kv_bytes / SWAP_BW
        } else {
            kv_bytes / (cfg.pcie.gpu_host_bw * HOST_STAGE_EFF)
        };
        StepBreakdown { weight: w, kv, compute: c, comm: 0.0 }
    };
    finish(cfg, b, "DeepSpeed", prefill, step)
}

/// FlexGen-like offloading.  `cfg.sparsity` selects the SparQ variant
/// (sparse transfers but 1.5x KV footprint — SparQ stores K twice).
/// Fig. 4 runs it tiered (GPU -> host -> SSD as KV grows); Fig. 12
/// configures the offload target to SSD, which is what `paper_base`
/// models (tier derived from capacity, host tier allowed).
pub fn flexgen(cfg: &SystemConfig, b: usize) -> Result<RunSummary, String> {
    let m = &cfg.model;
    // FlexGen's zig-zag block schedule double-buffers ~10 layers of
    // full-batch KV on the GPU during prefill — OOM at bs=128 (§VI-C)
    check_vram(cfg, b, 10)?;

    let footprint_mult = if cfg.sparsity.is_some() { 1.5 } else { 1.0 };
    let kv_total = (cfg.kv_bytes_total(b) as f64 * footprint_mult) as usize;
    let tier = if cfg.tiered { flexgen_tier(cfg, b, kv_total) } else { KvTier::Ssd };

    // sparse transfer fraction (SparQ reads r/d of K + k/s of K,V)
    let frac = cfg
        .sparsity
        .map(|sp| sp.transfer_fraction(m, cfg.input_len + cfg.output_len))
        .unwrap_or(1.0);

    let prefill = {
        let compute = prefill_gpu_compute(cfg, b);
        let kv_bytes = m.kv_bytes(b, cfg.input_len) as f64 * footprint_mult;
        let ship = match tier {
            KvTier::Vram => 0.0,
            KvTier::HostDram => kv_bytes / (cfg.pcie.gpu_host_bw * HOST_STAGE_EFF),
            KvTier::Ssd => {
                let ios = (kv_bytes / (128.0 * 1024.0)).ceil() as u64;
                pcie::transfer_time(&cfg.pcie, Path::SsdGpuViaHost, kv_bytes, ios)
                    / SSD_FS_EFF
            }
        };
        // FlexGen does not overlap prefill compute with KV shipping
        compute + ship
    };

    let step = move |s: usize| {
        let (w, c) = gpu_nonattn_step(cfg, b);
        let kv_bytes = m.kv_bytes(b, s) as f64 * frac;
        let kv = match tier {
            KvTier::Vram => m.n_layers as f64 * gpu::gpu_decode_attn_time(m, &cfg.gpu, b, s),
            KvTier::HostDram => kv_bytes / (cfg.pcie.gpu_host_bw * HOST_STAGE_EFF),
            KvTier::Ssd => {
                // sparse access shrinks the IO size (gathers), not just bytes
                // SparQ gathers coalesce into ~64 KiB reads (K^T rows are
                // contiguous in FlexGen's layout); dense streams 128 KiB
                let io_sz = if cfg.sparsity.is_some() { 64.0 * 1024.0 } else { 128.0 * 1024.0 };
                let ios = (kv_bytes / io_sz).ceil() as u64;
                pcie::transfer_time(&cfg.pcie, Path::SsdGpuViaHost, kv_bytes, ios) / SSD_FS_EFF
            }
        };
        StepBreakdown { weight: w, kv, compute: c, comm: 0.0 }
    };
    let label = if cfg.sparsity.is_some() { "FlexGen-SparQ" } else { "FlexGen" };
    finish(cfg, b, label, prefill, step)
}

/// FlexGen's tier choice for the whole run (end-of-generation KV size).
pub fn flexgen_tier(cfg: &SystemConfig, b: usize, kv_total: usize) -> KvTier {
    let m = &cfg.model;
    let act = 3 * b * cfg.input_len * m.d_model * FP16_BYTES;
    let reserve = 4 << 30;
    let gpu_budget =
        (cfg.gpu.vram_bytes.saturating_sub(m.weight_bytes() + act + reserve)) / 2;
    if kv_total <= gpu_budget {
        KvTier::Vram
    } else if kv_total <= cfg.host.usable_dram() {
        KvTier::HostDram
    } else {
        KvTier::Ssd
    }
}

fn prefill_gpu_compute(cfg: &SystemConfig, b: usize) -> f64 {
    cfg.model.n_layers as f64
        * gpu::gpu_prefill_layer_time(&cfg.model, &cfg.gpu, b, cfg.input_len)
}

fn finish(
    cfg: &SystemConfig,
    b: usize,
    label: &str,
    prefill: f64,
    step: impl Fn(usize) -> StepBreakdown,
) -> Result<RunSummary, String> {
    let (decode_s, bd) = integrate_decode(cfg, step);
    let total = prefill + decode_s;
    Ok(RunSummary {
        label: label.to_string(),
        batch: b,
        throughput: (b * cfg.output_len) as f64 / total,
        prefill_s: prefill,
        decode_s,
        decode_breakdown: bd,
        kv_bytes: cfg.kv_bytes_total(b),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::system::OffloadPolicy;

    fn cfg(p: OffloadPolicy) -> SystemConfig {
        SystemConfig::paper_base(p)
    }

    #[test]
    fn gpu_only_ooms_quickly() {
        // OPT-13B on 48 GB: KV for even bs=16 at 2K ctx doesn't fit
        assert!(gpu_only(&cfg(OffloadPolicy::GpuOnly), 16).is_err());
        assert!(gpu_only(&cfg(OffloadPolicy::GpuOnly), 4).is_ok());
    }

    #[test]
    fn flexgen_tiers_match_fig4_boundaries() {
        let c = cfg(OffloadPolicy::SsdViaHost);
        let t4 = flexgen_tier(&c, 4, c.kv_bytes_total(4));
        let t8 = flexgen_tier(&c, 8, c.kv_bytes_total(8));
        let t32 = flexgen_tier(&c, 32, c.kv_bytes_total(32));
        let t64 = flexgen_tier(&c, 64, c.kv_bytes_total(64));
        assert_eq!(t4, KvTier::Vram);
        assert_eq!(t8, KvTier::HostDram);
        assert_eq!(t32, KvTier::HostDram);
        assert_eq!(t64, KvTier::Ssd);
    }

    #[test]
    fn deepspeed_cliff_at_bs32() {
        // Fig. 4: throughput rises 8 -> 16, collapses at 32
        let c = cfg(OffloadPolicy::HostDram);
        let t8 = deepspeed(&c, 8).unwrap().throughput;
        let t16 = deepspeed(&c, 16).unwrap().throughput;
        let t32 = deepspeed(&c, 32).unwrap().throughput;
        assert!(t16 > t8, "t16 {t16} t8 {t8}");
        let ratio = t16 / t32;
        assert!((15.0..60.0).contains(&ratio), "cliff ratio {ratio} (paper: 32.6x)");
    }

    #[test]
    fn fig5_breakdown_weight_then_kv() {
        // small batch (VRAM tier): Weight access dominates;
        // large batch (SSD tier): KV access >= 90% (paper: 98.94%)
        let c = cfg(OffloadPolicy::SsdViaHost).tiered();
        let small = flexgen(&c, 4).unwrap().decode_breakdown;
        assert!(small.weight > small.kv, "{small:?}");
        let big = flexgen(&c, 64).unwrap().decode_breakdown;
        assert!(big.kv / big.total() > 0.9, "{big:?}");
    }

    #[test]
    fn sparq_variant_faster_but_fatter() {
        let c = cfg(OffloadPolicy::SsdViaHost);
        let dense = flexgen(&c, 64).unwrap();
        let sq = flexgen(&c.clone().with_default_sparsity(), 64).unwrap();
        assert!(sq.throughput > 1.5 * dense.throughput);
        // the 1.5x footprint pushes the host->SSD boundary earlier
        let kv32 = c.kv_bytes_total(32);
        assert_eq!(flexgen_tier(&c, 32, kv32), KvTier::HostDram);
        assert_eq!(flexgen_tier(&c, 32, (kv32 as f64 * 1.5) as usize), KvTier::Ssd);
    }
}
