//! `cargo bench --bench figures` — regenerates every paper table/figure
//! (criterion is unavailable offline; this is a plain harness=false bench
//! binary that times each figure's generation and prints the tables).

use std::time::Instant;

fn main() {
    // honour `cargo bench -- <filter>`
    let filter: Option<String> = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let mut total = 0.0;
    for (name, f) in instinfer::bench::registry() {
        if let Some(flt) = &filter {
            if !name.contains(flt.as_str()) {
                continue;
            }
        }
        let t0 = Instant::now();
        let table = f();
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        println!();
        table.print();
        println!("[bench {name}: generated in {dt:.3}s]");
    }
    println!("\nall figure benches done in {total:.2}s");
}
