//! `cargo bench --bench hotpath` — microbenchmarks of the request-path
//! hot spots (custom harness; median-of-N timing with warmup).
//!
//! Covered:
//!   topk          argtopk unit: heap vs full-sort selection
//!   sparse-dense  rust-native dense attention (CSD kernel arithmetic)
//!   sparse-sparf  rust-native SparF attention
//!   ftl-fetch     FTL token-group fetch (page decode path)
//!   csd-step      full in-storage attention step (dense + sparf)
//!   pjrt-decode   one PJRT decode-layer round trip (qkv+attn+post)
//!   e2e-step      full coordinator decode step, batch of 4

use instinfer::config::model::SparsityParams;
use instinfer::coordinator::{EngineConfig, InferenceEngine, Sequence, SlotManager};
use instinfer::csd::{AttnMode, InstCsd};
use instinfer::ftl::{FtlConfig, KvFtl, KvKind, StreamKey};
use instinfer::runtime::{HostTensor, Runtime};
use instinfer::sparse;
use instinfer::util::rng::Rng;
use instinfer::util::stats::percentile;
use instinfer::workload::Request;

fn time_it<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..3.min(iters) {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let p50 = percentile(&mut samples.clone(), 50.0);
    let p95 = percentile(&mut samples, 95.0);
    println!("{name:<28} p50 {p50:>10.2} us   p95 {p95:>10.2} us   ({iters} iters)");
}

fn main() {
    let filter: Option<String> = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let want = |n: &str| filter.as_ref().map_or(true, |f| n.contains(f.as_str()));
    let mut rng = Rng::new(0xBE7C);

    // ---- selection primitives --------------------------------------------
    if want("topk") {
        let xs: Vec<f32> = (0..2048).map(|_| rng.normal_f32()).collect();
        time_it("topk-heap k=256 n=2048", 200, || {
            std::hint::black_box(sparse::select::topk_mask_heap(&xs, 256));
        });
        time_it("topk-sort k=256 n=2048", 200, || {
            std::hint::black_box(sparse::select::topk_mask(&xs, 256));
        });
        time_it("topk-select k=256 n=2048", 200, || {
            std::hint::black_box(sparse::select::topk_mask_select(&xs, 256));
        });
    }

    // ---- sparse attention arithmetic --------------------------------------
    if want("sparse") {
        let (s, d) = (2048usize, 128usize);
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let k: Vec<f32> = (0..s * d).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..s * d).map(|_| rng.normal_f32()).collect();
        let vbar = sparse::v_mean(&v, d, s);
        time_it("sparse-dense s=2048 d=128", 100, || {
            std::hint::black_box(sparse::dense_attention(&q, &k, &v, s));
        });
        let sp = SparsityParams { r: 32, k: 256, m: 2, n: 16 };
        time_it("sparse-sparf 1/8 s=2048", 100, || {
            std::hint::black_box(sparse::sparf_attention(&q, &k, &v, &vbar, s, &sp));
        });
    }

    // ---- FTL fetch path ----------------------------------------------------
    if want("ftl") {
        let mut ftl = KvFtl::new(
            instinfer::config::hw::FlashSpec::tiny(),
            FtlConfig::micro_head(),
        )
        .unwrap();
        let key = StreamKey { slot: 0, layer: 0, head: 0 };
        for _ in 0..96 {
            let kr: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
            let vr: Vec<f32> = (0..32).map(|_| rng.normal_f32()).collect();
            ftl.append_token(key, &kr, &vr, 0.0).unwrap();
        }
        time_it("ftl-fetch 12 groups", 300, || {
            let groups: Vec<usize> = (0..12).collect();
            std::hint::black_box(ftl.fetch_token_groups(key, KvKind::K, &groups, 0.0).unwrap());
        });
        time_it("ftl-fetch 8 emb lanes", 300, || {
            let ch: Vec<usize> = (0..8).collect();
            std::hint::black_box(ftl.fetch_emb_channels(key, &ch, 96, 0.0).unwrap());
        });
    }

    // ---- full CSD attention step -------------------------------------------
    if want("csd") {
        let mut csd = InstCsd::micro_test();
        for t in 0..96 {
            let kr: Vec<f32> = (0..8 * 32).map(|_| rng.normal_f32()).collect();
            let vr: Vec<f32> = (0..8 * 32).map(|_| rng.normal_f32()).collect();
            csd.write_token(0, 0, &kr, &vr, t as f64).unwrap();
        }
        let q: Vec<f32> = (0..8 * 32).map(|_| rng.normal_f32()).collect();
        time_it("csd-step dense 8 heads s=96", 50, || {
            std::hint::black_box(
                csd.attention_layer(0, 0, &q, 96, AttnMode::Dense, 0.0).unwrap(),
            );
        });
        let sp = SparsityParams { r: 8, k: 12, m: 4, n: 8 };
        time_it("csd-step sparf 8 heads s=96", 50, || {
            std::hint::black_box(
                csd.attention_layer(0, 0, &q, 96, AttnMode::SparF(sp), 0.0).unwrap(),
            );
        });
    }

    // ---- PJRT + end-to-end -------------------------------------------------
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    if dir.join("manifest.json").exists() {
        if want("pjrt") {
            let rt = Runtime::open(&dir).unwrap();
            rt.warmup().unwrap();
            let m = rt.manifest.model.clone();
            let b = 4usize;
            let x = HostTensor::f32(
                vec![b, m.d_model],
                (0..b * m.d_model).map(|_| rng.normal_f32()).collect(),
            );
            time_it("pjrt qkv_proj b=4", 100, || {
                std::hint::black_box(rt.call("qkv_proj", b, 0, &[x.clone()]).unwrap());
            });
            let q = HostTensor::f32(
                vec![b, m.n_heads, m.d_head],
                (0..b * m.d_model).map(|_| rng.normal_f32()).collect(),
            );
            let kv = HostTensor::f32(
                vec![b, m.n_heads, m.max_seq, m.d_head],
                (0..b * m.n_heads * m.max_seq * m.d_head)
                    .map(|_| rng.normal_f32())
                    .collect(),
            );
            let lens = HostTensor::f32(vec![b], vec![64.0; b]);
            time_it("pjrt attn_dense b=4 s=128", 50, || {
                std::hint::black_box(
                    rt.call("attn_dense", b, 0, &[q.clone(), kv.clone(), kv.clone(), lens.clone()])
                        .unwrap(),
                );
            });
        }
        if want("e2e") {
            let rt = Runtime::open(&dir).unwrap();
            rt.warmup().unwrap();
            let mut eng = InferenceEngine::new(rt, EngineConfig::micro(2)).unwrap();
            let mut slots = SlotManager::new(16);
            let mut seqs: Vec<Sequence> = (0..4)
                .map(|i| {
                    Sequence::new(
                        Request {
                            id: i,
                            prompt: (0..16).map(|t| (t * 7 + i as i32) % 512).collect(),
                            max_new_tokens: 64,
                        },
                        slots.alloc().unwrap(),
                    )
                })
                .collect();
            eng.prefill(&mut seqs, 4).unwrap();
            time_it("e2e decode step b=4", 30, || {
                eng.decode_step(&mut seqs, 4).unwrap();
            });
        }
    } else {
        println!("(artifacts missing: skipping pjrt/e2e benches — run `make artifacts`)");
    }
}
