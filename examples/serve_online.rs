//! End-to-end ONLINE serving driver: open-loop Poisson arrivals through
//! the continuous-batching scheduler.
//!
//! Requests arrive on the simulated device clock while earlier requests
//! are mid-decode; each engine step admits new arrivals into free KV
//! slots (chunked prefill interleaved with decode), retires finished
//! sequences mid-flight, and preempts low-priority sequences to flash
//! when a high-priority request finds all seats taken.  Reports
//! per-request latency percentiles, per-step batch occupancy, and the
//! admission/retirement/preemption churn.
//!
//!     cargo run --release --example serve_online -- --requests 24 --rate 2000
//!
//! All `instinfer serve` flags work here (one shared [`ServeOpts`]
//! surface): `--overlap` disaggregates prefill and decode onto the two
//! pipelined engine streams, `--prefix-cache` shares sealed prompt
//! prefixes across requests (multi-turn workload, `--share-ratio`
//! controls the shared fraction).
//!
//! Runs with or without AOT artifacts (native backend synthesizes the
//! opt-micro model when `artifacts/` is absent).

use instinfer::coordinator::{run_open_loop, InferenceEngine, ServeOpts};
use instinfer::runtime::Runtime;
use instinfer::workload::{ArrivalGen, PrefixWorkloadGen, RequestSource, WorkloadGen};

fn main() -> anyhow::Result<()> {
    // example-specific defaults first; user args later (last write wins)
    let mut args: Vec<String> = [
        "--requests", "24", "--rate", "2000", "--batch", "8", "--gen", "12",
        "--profile", "chat", "--prefill-chunk", "2", "--slots", "32",
        "--hi-frac", "0.2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    args.extend(std::env::args().skip(1));
    let opts = ServeOpts::parse(&args)?;
    let gen = opts.gen.max(2);
    let rate = opts.arrival_rate.expect("--rate is pre-seeded");
    let dir = std::env::var("INSTINFER_ARTIFACTS").unwrap_or_else(|_| opts.artifacts.clone());

    let rt = Runtime::open(&dir)?;
    println!("serve_online: backend {}", rt.platform());
    rt.warmup()?;
    let meta = rt.manifest.model.clone();
    println!("{opts}");
    let mut engine = InferenceEngine::new(rt, opts.engine_config(&meta))?;

    let src: Box<dyn RequestSource> = if opts.prefix_cache {
        Box::new(PrefixWorkloadGen::new(
            1234,
            meta.vocab,
            (meta.prefill_seq / 2).max(1),
            gen,
            opts.share_ratio,
            meta.n,
            0.8,
            4,
        ))
    } else {
        Box::new(WorkloadGen::new(
            1234,
            meta.vocab,
            meta.max_seq,
            opts.profile,
            meta.prefill_seq / 2,
            gen,
        ))
    };
    let mut ag = ArrivalGen::new(src, 77, rate).with_high_priority_fraction(opts.hi_frac);
    let mut arrivals = ag.take(opts.requests);
    for a in arrivals.iter_mut() {
        a.req.prompt.truncate(meta.prefill_seq);
        a.req.max_new_tokens = a.req.max_new_tokens.clamp(2, gen);
    }
    println!(
        "{} requests, Poisson {rate} req/s (sim clock), {} seats, \
         chunked prefill {}/step{}\n",
        opts.requests,
        opts.batch,
        opts.prefill_chunk,
        if opts.overlap { ", overlapped prefill/decode streams" } else { "" }
    );

    let t0 = std::time::Instant::now();
    let report = run_open_loop(&mut engine, arrivals, opts.sched_config())?;
    let wall = t0.elapsed().as_secs_f64();

    let mut records = report.records.clone();
    records.sort_by_key(|r| r.id);
    for r in &records {
        println!(
            "req {:>3} prio {} arrive {:>8.4}s first-tok {:>8.4}s done {:>8.4}s \
             gen {:>3} preempt {}",
            r.id, r.priority, r.arrived_at, r.first_token_at, r.finished_at,
            r.generated.len(), r.preemptions,
        );
    }

    // mid-stream churn evidence: how many admissions happened while other
    // sequences were already decoding
    let overlapped = records
        .iter()
        .filter(|r| {
            records.iter().any(|o| {
                o.id != r.id && o.admitted_at < r.admitted_at && o.finished_at > r.admitted_at
            })
        })
        .count();
    println!("\n{overlapped}/{} admissions landed mid-decode of another request", records.len());

    println!("{}", report.summary(&engine.metrics));
    let occ = &engine.metrics.step_occupancy;
    if !occ.is_empty() {
        let show = occ.len().min(48);
        let head: Vec<String> = occ[..show].iter().map(|o| o.to_string()).collect();
        println!(
            "per-step occupancy ({} steps{}): {}",
            occ.len(),
            if occ.len() > show { ", first 48 shown" } else { "" },
            head.join(" ")
        );
    }
    println!("{}", engine.metrics.report());
    println!(
        "wall {wall:.2}s | sim end {:.4}s | {:.1} tok/s (sim) | preemptions {}",
        report.sim_end,
        report.total_generated() as f64 / report.sim_end.max(1e-12),
        report.preemptions,
    );
    if engine.shards.n_csds() > 1 {
        let st = &engine.shards.stats;
        println!(
            "shards ({} x {}): attn {:.6}s | all-reduce {:.6}s | mean barrier \
             skew {:.2}us | stragglers {:?}",
            engine.shards.n_csds(),
            opts.shard_policy.label(),
            st.attn_span_s,
            st.merge_span_s,
            engine.shards.clock.mean_skew_s() * 1e6,
            engine.shards.clock.straggler,
        );
    }
    if opts.prefix_cache {
        let (mut attaches, mut toks) = (0u64, 0u64);
        for q in engine.csds() {
            attaches += q.csd.ftl.counters.prefix_attaches;
            toks += q.csd.ftl.counters.prefix_tokens_attached;
        }
        println!(
            "prefix cache: {attaches} attaches, {toks} shared tokens attached, \
             {} prompt tokens skipped at prefill",
            engine.metrics.prefix_hit_tokens,
        );
    }
    Ok(())
}
