//! End-to-end ONLINE serving driver: open-loop Poisson arrivals through
//! the continuous-batching scheduler.
//!
//! Requests arrive on the simulated device clock while earlier requests
//! are mid-decode; each engine step admits new arrivals into free KV
//! slots (chunked prefill interleaved with decode), retires finished
//! sequences mid-flight, and preempts low-priority sequences to flash
//! when a high-priority request finds all seats taken.  Reports
//! per-request latency percentiles, per-step batch occupancy, and the
//! admission/retirement/preemption churn.
//!
//!     cargo run --release --example serve_online -- --requests 24 --rate 2000
//!
//! Pass `--overlap` to disaggregate prefill and decode onto the two
//! pipelined engine streams (same outputs, decoupled TTFT).
//!
//! Runs with or without AOT artifacts (native backend synthesizes the
//! opt-micro model when `artifacts/` is absent).

use instinfer::coordinator::{run_open_loop, EngineConfig, InferenceEngine, SchedConfig};
use instinfer::runtime::Runtime;
use instinfer::shard::ShardPolicy;
use instinfer::workload::{ArrivalGen, LengthProfile, WorkloadGen};

fn flag(args: &[String], name: &str, default: f64) -> f64 {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_req = flag(&args, "--requests", 24.0) as usize;
    let rate = flag(&args, "--rate", 2000.0); // req per simulated second
    let batch = flag(&args, "--batch", 8.0) as usize;
    let gen = (flag(&args, "--steps", 12.0) as usize).max(2);
    let sparse = args.iter().any(|a| a == "--sparse");
    let overlap = args.iter().any(|a| a == "--overlap");
    let n_csds = flag(&args, "--n-csds", 2.0) as usize;
    let shard_policy = ShardPolicy::parse(
        args.iter()
            .position(|a| a == "--shard-policy")
            .and_then(|i| args.get(i + 1))
            .map(|s| s.as_str())
            .unwrap_or("stripe"),
    )?;
    if sparse && shard_policy == ShardPolicy::Context {
        anyhow::bail!("--shard-policy context supports dense attention only (drop --sparse)");
    }
    if n_csds == 0 {
        anyhow::bail!("--n-csds must be >= 1");
    }
    let dir = std::env::var("INSTINFER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    let rt = Runtime::open(&dir)?;
    println!("serve_online: backend {}", rt.platform());
    rt.warmup()?;
    let meta = rt.manifest.model.clone();
    let cfg = EngineConfig::micro_for(&meta, n_csds, sparse).sharded(shard_policy);
    let mut engine = InferenceEngine::new(rt, cfg)?;

    let wg = WorkloadGen::new(
        1234, meta.vocab, meta.max_seq, LengthProfile::Chat, meta.prefill_seq / 2, gen,
    );
    let mut ag = ArrivalGen::new(wg, 77, rate).with_high_priority_fraction(0.2);
    let mut arrivals = ag.take(n_req);
    for a in arrivals.iter_mut() {
        a.req.prompt.truncate(meta.prefill_seq);
        a.req.max_new_tokens = a.req.max_new_tokens.clamp(2, gen);
    }
    println!(
        "{n_req} requests, Poisson {rate} req/s (sim clock), {batch} seats, \
         chunked prefill 2/step{}\n",
        if overlap { ", overlapped prefill/decode streams" } else { "" }
    );

    let t0 = std::time::Instant::now();
    let report = run_open_loop(
        &mut engine,
        arrivals,
        SchedConfig::serving(batch, 2, 32).overlapped(overlap),
    )?;
    let wall = t0.elapsed().as_secs_f64();

    let mut records = report.records.clone();
    records.sort_by_key(|r| r.id);
    for r in &records {
        println!(
            "req {:>3} prio {} arrive {:>8.4}s first-tok {:>8.4}s done {:>8.4}s \
             gen {:>3} preempt {}",
            r.id, r.priority, r.arrived_at, r.first_token_at, r.finished_at,
            r.generated.len(), r.preemptions,
        );
    }

    // mid-stream churn evidence: how many admissions happened while other
    // sequences were already decoding
    let overlapped = records
        .iter()
        .filter(|r| {
            records.iter().any(|o| {
                o.id != r.id && o.admitted_at < r.admitted_at && o.finished_at > r.admitted_at
            })
        })
        .count();
    println!("\n{overlapped}/{} admissions landed mid-decode of another request", records.len());

    println!("{}", report.summary(&engine.metrics));
    let occ = &engine.metrics.step_occupancy;
    if !occ.is_empty() {
        let show = occ.len().min(48);
        let head: Vec<String> = occ[..show].iter().map(|o| o.to_string()).collect();
        println!(
            "per-step occupancy ({} steps{}): {}",
            occ.len(),
            if occ.len() > show { ", first 48 shown" } else { "" },
            head.join(" ")
        );
    }
    println!("{}", engine.metrics.report());
    println!(
        "wall {wall:.2}s | sim end {:.4}s | {:.1} tok/s (sim) | preemptions {}",
        report.sim_end,
        report.total_generated() as f64 / report.sim_end.max(1e-12),
        report.preemptions,
    );
    if engine.shards.n_csds() > 1 {
        let st = &engine.shards.stats;
        println!(
            "shards ({} x {}): attn {:.6}s | all-reduce {:.6}s | mean barrier \
             skew {:.2}us | stragglers {:?}",
            engine.shards.n_csds(),
            shard_policy.label(),
            st.attn_span_s,
            st.merge_span_s,
            engine.shards.clock.mean_skew_s() * 1e6,
            engine.shards.clock.straggler,
        );
    }
    Ok(())
}
