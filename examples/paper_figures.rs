//! Regenerate every table and figure of the paper's evaluation in one go
//! (the same code `instinfer bench all` runs).
//!
//!     cargo run --release --example paper_figures

fn main() {
    instinfer::bench::run_all();
}
