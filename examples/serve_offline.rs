//! End-to-end offline serving driver (the EXPERIMENTS.md E2E run).
//!
//! Loads the AOT-compiled opt-micro model, serves batched offline
//! requests through the full three-layer stack — rust coordinator ->
//! PJRT executables (GPU-side operators) -> simulated InstCSD array
//! (flash-resident KV + in-storage attention) — and reports throughput,
//! latency, CSD unit breakdown, and flash statistics for BOTH the dense
//! and SparF attention modes.
//!
//!     cargo run --release --example serve_offline -- --batch 8 --steps 16
//!
//! Flags are the shared [`ServeOpts`] serve surface (`--requests`,
//! `--batch`, `--gen`/`--steps`, ...); the dense/sparse sweep below
//! overrides `--sparse` per mode.

use instinfer::coordinator::{
    run_closed_loop, InferenceEngine, OfflineBatcher, Sequence, ServeOpts, SlotManager,
};
use instinfer::runtime::Runtime;
use instinfer::util::stats::percentile;
use instinfer::workload::{LengthProfile, WorkloadGen};

fn run_mode(dir: &str, opts: &ServeOpts, sparse: bool) -> anyhow::Result<()> {
    let rt = Runtime::open(dir)?;
    let meta = rt.manifest.model.clone();
    let buckets = rt.manifest.batch_buckets.clone();
    rt.warmup()?;
    let mut mode_opts = opts.clone();
    mode_opts.sparse = sparse;
    let mut engine = InferenceEngine::new(rt, mode_opts.engine_config(&meta))?;
    let gen = opts.gen;
    let mut wg = WorkloadGen::new(
        1234, meta.vocab, meta.max_seq, LengthProfile::Chat, meta.prefill_seq / 2, gen,
    );
    let mut batcher = OfflineBatcher::new(buckets, opts.batch);
    for mut r in wg.batch(opts.requests) {
        r.prompt.truncate(meta.prefill_seq);
        r.max_new_tokens = r.max_new_tokens.clamp(2, gen);
        batcher.push(r);
    }
    let mut slots = SlotManager::new(64);
    let t0 = std::time::Instant::now();
    let mut done_all = Vec::new();
    while let Some((reqs, bucket)) = batcher.next_batch() {
        let seqs: Vec<Sequence> = reqs
            .into_iter()
            .map(|r| Sequence::new(r, slots.alloc().unwrap()))
            .collect();
        let done = engine.generate(seqs, bucket)?;
        for s in &done {
            slots.release(s.slot).unwrap();
        }
        done_all.extend(done);
    }
    let wall = t0.elapsed().as_secs_f64();

    let mode = if sparse { "InstI-SparF" } else { "InstI-Dense" };
    println!("== {mode} ==");
    println!("{}", engine.metrics.report());
    println!(
        "wall {:.2}s  e2e {:.1} tok/s  simulated-device {:.4}s",
        wall,
        engine.metrics.tokens_generated as f64 / wall,
        engine.sim_now
    );
    let mut lats = engine.metrics.batch_latencies.clone();
    if !lats.is_empty() {
        println!(
            "batch latency p50 {:.3}s p95 {:.3}s",
            percentile(&mut lats.clone(), 50.0),
            percentile(&mut lats, 95.0)
        );
    }
    let mut reads = 0u64;
    let mut programs = 0u64;
    let mut wa = 0.0;
    for q in engine.csds() {
        reads += q.csd.ftl.array.counters.page_reads;
        programs += q.csd.ftl.array.counters.page_programs;
        wa += q.csd.ftl.write_amplification();
    }
    println!(
        "flash: {} page reads, {} programs, write amplification {:.2}",
        reads,
        programs,
        wa / engine.csds().len() as f64
    );
    let u = &engine.metrics.units;
    if u.total() > 0.0 {
        println!(
            "CSD units: argtopk {:.1}% flash {:.1}% filter {:.1}% logit0 {:.1}% \
             logit {:.1}% attend {:.1}%",
            100.0 * u.argtopk / u.total(),
            100.0 * u.flash_read / u.total(),
            100.0 * u.nfc_filter / u.total(),
            100.0 * u.logit0 / u.total(),
            100.0 * u.logit / u.total(),
            100.0 * u.attend / u.total(),
        );
    }
    println!();
    Ok(())
}

/// The same closed-loop workload through the continuous-batching
/// scheduler: stragglers no longer hold their bucket hostage, so the
/// drained-queue throughput is a lower bound for this path.
fn run_continuous(dir: &str, opts: &ServeOpts) -> anyhow::Result<f64> {
    let rt = Runtime::open(dir)?;
    let meta = rt.manifest.model.clone();
    rt.warmup()?;
    let mut engine = InferenceEngine::new(rt, opts.engine_config(&meta))?;
    let gen = opts.gen;
    let mut wg = WorkloadGen::new(
        1234, meta.vocab, meta.max_seq, LengthProfile::Chat, meta.prefill_seq / 2, gen,
    );
    let reqs = wg
        .batch(opts.requests)
        .into_iter()
        .map(|mut r| {
            r.prompt.truncate(meta.prefill_seq);
            r.max_new_tokens = r.max_new_tokens.clamp(2, gen);
            r
        })
        .collect();
    let report = run_closed_loop(&mut engine, reqs, opts.sched_config())?;
    let tput = report.total_generated() as f64 / report.sim_end.max(1e-12);
    println!("== InstI-Dense, continuous batching (same closed-loop Chat workload) ==");
    println!("{}", report.summary(&engine.metrics));
    println!("sim throughput {tput:.1} tok/s over {:.4}s simulated\n", report.sim_end);
    Ok(tput)
}

fn main() -> anyhow::Result<()> {
    // example-specific defaults first; user args later (last write wins)
    let mut args: Vec<String> = ["--requests", "12", "--batch", "8", "--gen", "12"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    args.extend(std::env::args().skip(1));
    let mut opts = ServeOpts::parse(&args)?;
    opts.gen = opts.gen.max(2);
    let dir = std::env::var("INSTINFER_ARTIFACTS").unwrap_or_else(|_| opts.artifacts.clone());
    println!(
        "serve_offline: {} requests, batch {}, {} new tokens each\n",
        opts.requests, opts.batch, opts.gen
    );
    run_mode(&dir, &opts, false)?;
    run_mode(&dir, &opts, true)?;
    run_continuous(&dir, &opts)?;
    Ok(())
}
