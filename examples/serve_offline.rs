//! End-to-end offline serving driver (the EXPERIMENTS.md E2E run).
//!
//! Loads the AOT-compiled opt-micro model, serves batched offline
//! requests through the full three-layer stack — rust coordinator ->
//! PJRT executables (GPU-side operators) -> simulated InstCSD array
//! (flash-resident KV + in-storage attention) — and reports throughput,
//! latency, CSD unit breakdown, and flash statistics for BOTH the dense
//! and SparF attention modes.
//!
//!     cargo run --release --example serve_offline -- --batch 8 --steps 16

use instinfer::coordinator::{
    run_closed_loop, EngineConfig, InferenceEngine, OfflineBatcher, SchedConfig, Sequence,
    SlotManager,
};
use instinfer::runtime::Runtime;
use instinfer::util::stats::percentile;
use instinfer::workload::{LengthProfile, WorkloadGen};

fn flag(args: &[String], name: &str, default: usize) -> usize {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn run_mode(dir: &str, sparse: bool, n_req: usize, batch: usize, gen: usize) -> anyhow::Result<()> {
    let rt = Runtime::open(dir)?;
    let meta = rt.manifest.model.clone();
    let buckets = rt.manifest.batch_buckets.clone();
    rt.warmup()?;
    let cfg = EngineConfig::micro_for(&meta, 2, sparse);
    let mut engine = InferenceEngine::new(rt, cfg)?;
    let mut wg = WorkloadGen::new(
        1234, meta.vocab, meta.max_seq, LengthProfile::Chat, meta.prefill_seq / 2, gen,
    );
    let mut batcher = OfflineBatcher::new(buckets, batch);
    for mut r in wg.batch(n_req) {
        r.prompt.truncate(meta.prefill_seq);
        r.max_new_tokens = r.max_new_tokens.clamp(2, gen);
        batcher.push(r);
    }
    let mut slots = SlotManager::new(64);
    let t0 = std::time::Instant::now();
    let mut done_all = Vec::new();
    while let Some((reqs, bucket)) = batcher.next_batch() {
        let seqs: Vec<Sequence> = reqs
            .into_iter()
            .map(|r| Sequence::new(r, slots.alloc().unwrap()))
            .collect();
        let done = engine.generate(seqs, bucket)?;
        for s in &done {
            slots.release(s.slot).unwrap();
        }
        done_all.extend(done);
    }
    let wall = t0.elapsed().as_secs_f64();

    let mode = if sparse { "InstI-SparF" } else { "InstI-Dense" };
    println!("== {mode} ==");
    println!("{}", engine.metrics.report());
    println!(
        "wall {:.2}s  e2e {:.1} tok/s  simulated-device {:.4}s",
        wall,
        engine.metrics.tokens_generated as f64 / wall,
        engine.sim_now
    );
    let mut lats = engine.metrics.batch_latencies.clone();
    if !lats.is_empty() {
        println!(
            "batch latency p50 {:.3}s p95 {:.3}s",
            percentile(&mut lats.clone(), 50.0),
            percentile(&mut lats, 95.0)
        );
    }
    let mut reads = 0u64;
    let mut programs = 0u64;
    let mut wa = 0.0;
    for q in engine.csds() {
        reads += q.csd.ftl.array.counters.page_reads;
        programs += q.csd.ftl.array.counters.page_programs;
        wa += q.csd.ftl.write_amplification();
    }
    println!(
        "flash: {} page reads, {} programs, write amplification {:.2}",
        reads,
        programs,
        wa / engine.csds().len() as f64
    );
    let u = &engine.metrics.units;
    if u.total() > 0.0 {
        println!(
            "CSD units: argtopk {:.1}% flash {:.1}% filter {:.1}% logit0 {:.1}% \
             logit {:.1}% attend {:.1}%",
            100.0 * u.argtopk / u.total(),
            100.0 * u.flash_read / u.total(),
            100.0 * u.nfc_filter / u.total(),
            100.0 * u.logit0 / u.total(),
            100.0 * u.logit / u.total(),
            100.0 * u.attend / u.total(),
        );
    }
    println!();
    Ok(())
}

/// The same closed-loop workload through the continuous-batching
/// scheduler: stragglers no longer hold their bucket hostage, so the
/// drained-queue throughput is a lower bound for this path.
fn run_continuous(dir: &str, n_req: usize, batch: usize, gen: usize) -> anyhow::Result<f64> {
    let rt = Runtime::open(dir)?;
    let meta = rt.manifest.model.clone();
    rt.warmup()?;
    let mut engine = InferenceEngine::new(rt, EngineConfig::micro(2))?;
    let mut wg = WorkloadGen::new(
        1234, meta.vocab, meta.max_seq, LengthProfile::Chat, meta.prefill_seq / 2, gen,
    );
    let reqs = wg
        .batch(n_req)
        .into_iter()
        .map(|mut r| {
            r.prompt.truncate(meta.prefill_seq);
            r.max_new_tokens = r.max_new_tokens.clamp(2, gen);
            r
        })
        .collect();
    let report = run_closed_loop(
        &mut engine,
        reqs,
        SchedConfig::serving(batch, 4, 64),
    )?;
    let tput = report.total_generated() as f64 / report.sim_end.max(1e-12);
    println!("== InstI-Dense, continuous batching (same closed-loop Chat workload) ==");
    println!("{}", report.summary(&engine.metrics));
    println!("sim throughput {tput:.1} tok/s over {:.4}s simulated\n", report.sim_end);
    Ok(tput)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_req = flag(&args, "--requests", 12);
    let batch = flag(&args, "--batch", 8);
    let gen = flag(&args, "--steps", 12).max(2);
    let dir = std::env::var("INSTINFER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    println!(
        "serve_offline: {n_req} requests, batch {batch}, {gen} new tokens each\n"
    );
    run_mode(&dir, false, n_req, batch, gen)?;
    run_mode(&dir, true, n_req, batch, gen)?;
    run_continuous(&dir, n_req, batch, gen)?;
    Ok(())
}
