//! CSD design-space explorer: sweep flash geometry and SparF group sizes
//! on the functional engine and report page traffic, bandwidth use and
//! write amplification — the co-design loop of paper §IV-C.
//!
//!     cargo run --release --example csd_explorer

use instinfer::config::hw::{FlashPathConfig, FlashSpec};
use instinfer::config::model::SparsityParams;
use instinfer::csd::{AttnMode, InstCsd};
use instinfer::config::hw::CsdSpec;
use instinfer::ftl::FtlConfig;
use instinfer::util::rng::Rng;
use instinfer::util::table::{eng, Table};

fn explore(channels: usize, n_group: usize, sparse: bool) -> anyhow::Result<Vec<String>> {
    let d = 32usize;
    let page_bytes = n_group * d * 2;
    let flash = FlashSpec {
        channels,
        dies_per_channel: 2,
        planes_per_die: 1,
        blocks_per_plane: 64,
        pages_per_block: 64,
        page_bytes,
        channel_bw: 1.4e9,
        read_us: 50.0,
        program_us: 600.0,
        erase_ms: 3.0,
        path: FlashPathConfig::legacy(),
    };
    let spec = CsdSpec {
        name: "explorer",
        flash,
        engine_flops: 768.0 * 285e6 * 2.0,
        clock_hz: 285e6,
        dram_bytes: 64 << 20,
        attn_kernels: 2,
        argtopk_elems_per_s: 285e6,
        filter_bw_per_channel: flash.channel_bw,
        dram_bw: 4.2e9,
        hot_tier_bytes: 0, // the explorer measures raw flash behaviour
        kv_capacity_bytes: flash.usable_capacity_bytes() as u64,
    };
    let mut csd = InstCsd::new(spec, FtlConfig { d_head: d, m: 4, n: n_group })?;

    let mut rng = Rng::new(99);
    let s_len = 96usize;
    for t in 0..s_len {
        let k: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        csd.write_token(0, 0, &k, &v, t as f64 * 1e-6)?;
    }
    let before = csd.ftl.array.counters.page_reads;
    csd.ftl.array.reset_timing();
    let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let mode = if sparse {
        AttnMode::SparF(SparsityParams { r: 8, k: 12, m: 4, n: n_group })
    } else {
        AttnMode::Dense
    };
    let key = instinfer::ftl::StreamKey { slot: 0, layer: 0, head: 0 };
    let (_, t_done, bd) = csd.attention_head(key, &q, s_len, mode, 0.0)?;
    let reads = csd.ftl.array.counters.page_reads - before;
    Ok(vec![
        channels.to_string(),
        n_group.to_string(),
        if sparse { "SparF" } else { "dense" }.into(),
        reads.to_string(),
        eng(t_done * 1e6),
        eng(bd.flash_read * 1e6),
        eng(csd.ftl.write_amplification()),
    ])
}

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(
        "CSD design space: one attention step over a 96-token stream",
        &["channels", "group n", "mode", "page reads", "step us", "flash us", "WA"],
    );
    for &channels in &[2usize, 4, 8] {
        for &n in &[4usize, 8, 16] {
            for &sparse in &[false, true] {
                t.row(explore(channels, n, sparse)?);
            }
        }
    }
    t.print();
    println!(
        "\nreading guide: larger groups cut page count for dense streaming but\n\
         over-fetch for sparse gathers; more channels cut step latency; WA\n\
         stays ~1.5 (K stored twice) regardless — the paper's §IV-C tradeoff."
    );
    Ok(())
}
