//! Quickstart: open the AOT artifacts, validate them against the jax
//! golden record, and generate a few tokens through the full InstInfer
//! stack (PJRT "GPU" + simulated CSD with in-storage attention).
//!
//!     make artifacts && cargo run --release --example quickstart

use instinfer::coordinator::{EngineConfig, InferenceEngine, Sequence, SlotManager};
use instinfer::runtime::{golden, Runtime};
use instinfer::workload::Request;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("INSTINFER_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let rt = Runtime::open(&dir)?;
    println!("PJRT platform: {}", rt.platform());

    // 1) the python<->rust seam: every artifact reproduces jax bit-closely
    for r in golden::check_all(&rt, 2e-4)? {
        println!("golden {:<16} max_abs_err {:.2e}", r.exe, r.max_abs_err);
    }

    // 2) run a tiny offline batch through the whole system
    let mut engine = InferenceEngine::new(rt, EngineConfig::micro(2))?;
    let mut slots = SlotManager::new(8);
    let prompts = [
        vec![11, 45, 209, 17, 300, 4],
        vec![7, 7, 7, 99, 123, 54, 32, 10],
    ];
    let seqs: Vec<Sequence> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            Sequence::new(
                Request { id: i as u64, prompt: p.clone(), max_new_tokens: 8 },
                slots.alloc().unwrap(),
            )
        })
        .collect();
    let done = engine.generate(seqs, 4)?;
    for s in &done {
        println!("prompt {:?} -> generated {:?}", s.req.prompt, s.generated);
    }
    println!("{}", engine.metrics.report());
    println!("simulated CSD device time: {:.6}s", engine.sim_now);
    println!("quickstart OK");
    Ok(())
}
