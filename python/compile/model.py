"""L2: OPT-style decoder-only transformer, split along the paper's GPU/CSD cut.

The paper partitions each decode step as (Fig. 2, §III-B):

    GPU : embed -> LN -> QKV projection            (`embed_decode`, `qkv_proj`)
    CSD : decoding-phase attention over the KV cache (`attn_dense`/`attn_sparf`)
    GPU : O projection -> FFN -> (last layer) logits (`post_attn`, `logits`)

and the whole prefill phase stays on the GPU (`embed_prefill`,
`prefill_block`).  Each of these groups is its own AOT artifact so the rust
coordinator can schedule them independently, exactly like the real system
schedules GPU kernels vs CSD NVMe commands.

All functions are pure: weights are explicit arguments (the artifacts are
layer-agnostic; the rust side binds layer i's tensors at call time).
Everything is float32 — the CPU PJRT path has no native FP16; byte-level
accounting elsewhere uses the paper's FP16 sizes (DESIGN.md §1).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels import dense as kdense
from .kernels import ref as kref
from .kernels import sparf as ksparf


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + SparF hyper-parameters.

    m = embedding-group size (channels per embedding-indexed flash page),
    n = token-group size (tokens per token-indexed flash page),
    r, k = SparF top-r channels / top-k tokens (compression = r/d = k-ish/S).
    """

    name: str
    vocab: int
    d_model: int
    n_heads: int
    d_head: int
    d_ffn: int
    n_layers: int
    max_seq: int
    r: int
    k: int
    m: int
    n: int

    @property
    def bh(self) -> int:
        return self.n_heads * self.d_head


# Functional-plane model: small enough that CPU PJRT runs it interactively,
# shaped like OPT (pre-LN, learned positions, tied unembedding).
SMALL = ModelConfig(
    name="opt-micro-14m",
    vocab=512,
    d_model=256,
    n_heads=8,
    d_head=32,
    d_ffn=1024,
    n_layers=4,
    max_seq=128,
    r=8,      # 1/4 of d_head
    k=16,     # 1/8 of max_seq
    m=4,
    n=8,
)

# Timing-plane shape reference (never lowered — drives the rust DES).
OPT_13B = ModelConfig(
    name="opt-13b",
    vocab=50272,
    d_model=5120,
    n_heads=40,
    d_head=128,
    d_ffn=20480,
    n_layers=40,
    max_seq=2048,
    r=32,     # 1/4 of d_head
    k=256,    # 1/8 of max_seq
    m=8,
    n=16,     # 16 tokens x 128 x FP16 = 4 KiB page (paper §IV-C)
)


# --------------------------------------------------------------------------
# Parameter initialisation (deterministic; shared with golden generation)
# --------------------------------------------------------------------------

LAYER_SLOTS = [
    "ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv",
    "wo", "bo", "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
]


def layer_slot_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    D, F = cfg.d_model, cfg.d_ffn
    return {
        "ln1_g": (D,), "ln1_b": (D,),
        "wq": (D, D), "bq": (D,),
        "wk": (D, D), "bk": (D,),
        "wv": (D, D), "bv": (D,),
        "wo": (D, D), "bo": (D,),
        "ln2_g": (D,), "ln2_b": (D,),
        "w1": (D, F), "b1": (F,),
        "w2": (F, D), "b2": (D,),
    }


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Deterministic OPT-style init; keys are flat dotted names."""
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jnp.ndarray] = {}

    def nxt():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    def dense_init(shape, fan_in):
        return jax.random.normal(nxt(), shape, jnp.float32) * (fan_in ** -0.5)

    params["tok_emb"] = dense_init((cfg.vocab, cfg.d_model), cfg.d_model)
    params["pos_emb"] = dense_init((cfg.max_seq, cfg.d_model), cfg.d_model)
    shapes = layer_slot_shapes(cfg)
    for layer in range(cfg.n_layers):
        for slot in LAYER_SLOTS:
            shape = shapes[slot]
            name = f"layers.{layer}.{slot}"
            if slot.startswith(("ln",)) and slot.endswith("_g"):
                params[name] = jnp.ones(shape, jnp.float32)
            elif len(shape) == 1:
                params[name] = jnp.zeros(shape, jnp.float32)
            else:
                params[name] = dense_init(shape, shape[0])
    params["ln_f_g"] = jnp.ones((cfg.d_model,), jnp.float32)
    params["ln_f_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return params


# --------------------------------------------------------------------------
# Operator groups (one AOT artifact each)
# --------------------------------------------------------------------------


def layer_norm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def embed_decode(ids, pos, tok_emb, pos_emb):
    """Decode-step embedding: ids,pos (B,) int32 -> x (B, D)."""
    return tok_emb[ids] + pos_emb[pos]


def embed_prefill(ids, tok_emb, pos_emb):
    """Prefill embedding: ids (B, S) int32 -> x (B, S, D)."""
    B, S = ids.shape
    return tok_emb[ids] + pos_emb[jnp.arange(S)][None, :, :]


def qkv_proj(x, ln1_g, ln1_b, wq, bq, wk, bk, wv, bv, *, cfg: ModelConfig):
    """Pre-LN QKV projection: x (B, D) -> q, k, v each (B, H, d_head)."""
    B = x.shape[0]
    h = layer_norm(x, ln1_g, ln1_b)
    q = (h @ wq + bq).reshape(B, cfg.n_heads, cfg.d_head)
    k = (h @ wk + bk).reshape(B, cfg.n_heads, cfg.d_head)
    v = (h @ wv + bv).reshape(B, cfg.n_heads, cfg.d_head)
    return q, k, v


def _to_bh(t, cfg: ModelConfig):
    """(B, H, S, d) -> (B*H, S, d) / (B, H, d) -> (B*H, d)."""
    return t.reshape((-1,) + t.shape[2:])


def attn_dense(q, K, V, lens, *, cfg: ModelConfig):
    """Decode attention (dense) — the InstI-Dense CSD engine artifact.

    q (B,H,d); K,V (B,H,S,d); lens (B,) f32 -> (B,H,d).
    """
    B = q.shape[0]
    lens_bh = jnp.repeat(lens, cfg.n_heads)
    out = kdense.dense_decode_attention(
        _to_bh(q, cfg), _to_bh(K, cfg), _to_bh(V, cfg), lens_bh, group=cfg.n
    )
    return out.reshape(B, cfg.n_heads, cfg.d_head)


def attn_sparf(q, K, V, lens, *, cfg: ModelConfig):
    """Decode attention (SparF, Algorithm 1) — the InstI-SparF CSD artifact."""
    B = q.shape[0]
    lens_bh = jnp.repeat(lens, cfg.n_heads)
    out = ksparf.sparf_decode_attention(
        _to_bh(q, cfg), _to_bh(K, cfg), _to_bh(V, cfg), lens_bh,
        r=cfg.r, k=cfg.k, m=cfg.m, n=cfg.n,
    )
    return out.reshape(B, cfg.n_heads, cfg.d_head)


def post_attn(x, attn, wo, bo, ln2_g, ln2_b, w1, b1, w2, b2, *, cfg: ModelConfig):
    """O projection + residual + FFN: x (B,D), attn (B,H,d) -> x' (B,D)."""
    B = x.shape[0]
    o = attn.reshape(B, cfg.d_model) @ wo + bo
    x = x + o
    h = layer_norm(x, ln2_g, ln2_b)
    f = jax.nn.relu(h @ w1 + b1) @ w2 + b2
    return x + f


def logits(x, ln_f_g, ln_f_b, tok_emb):
    """Final LN + tied unembedding; returns (logits (B,V), greedy ids (B,))."""
    h = layer_norm(x, ln_f_g, ln_f_b)
    lg = h @ tok_emb.T
    return lg, jnp.argmax(lg, axis=-1).astype(jnp.int32)


def prefill_block(
    x, ln1_g, ln1_b, wq, bq, wk, bk, wv, bv,
    wo, bo, ln2_g, ln2_b, w1, b1, w2, b2, *, cfg: ModelConfig,
):
    """One decoder block over a full prompt (GPU-resident in the paper).

    x (B, S, D) -> (x' (B, S, D), K (B, H, S, d), V (B, H, S, d)).
    The returned K/V are what the coordinator ships to the CSD layer-wise,
    overlapped with the next block's compute (paper §IV-D).
    """
    B, S, D = x.shape
    h = layer_norm(x, ln1_g, ln1_b)
    q = (h @ wq + bq).reshape(B, S, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = (h @ wk + bk).reshape(B, S, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = (h @ wv + bv).reshape(B, S, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    ar = kref.causal_attention_bh(
        q.reshape(B * cfg.n_heads, S, cfg.d_head),
        k.reshape(B * cfg.n_heads, S, cfg.d_head),
        v.reshape(B * cfg.n_heads, S, cfg.d_head),
    ).reshape(B, cfg.n_heads, S, cfg.d_head)
    o = ar.transpose(0, 2, 1, 3).reshape(B, S, D) @ wo + bo
    x = x + o
    h2 = layer_norm(x, ln2_g, ln2_b)
    f = jax.nn.relu(h2 @ w1 + b1) @ w2 + b2
    return x + f, k, v


# --------------------------------------------------------------------------
# Whole-model reference paths (tests + golden only; never lowered)
# --------------------------------------------------------------------------


def layer_weights(params: Dict[str, jnp.ndarray], i: int):
    return {s: params[f"layers.{i}.{s}"] for s in LAYER_SLOTS}


def reference_prefill(params, cfg: ModelConfig, ids):
    """Full prefill: ids (B, S) -> (x (B,S,D), K,V lists per layer)."""
    x = embed_prefill(ids, params["tok_emb"], params["pos_emb"])
    Ks, Vs = [], []
    for i in range(cfg.n_layers):
        w = layer_weights(params, i)
        x, K, V = prefill_block(x, *[w[s] for s in LAYER_SLOTS], cfg=cfg)
        Ks.append(K)
        Vs.append(V)
    return x, Ks, Vs


def reference_decode_step(params, cfg: ModelConfig, ids, pos, Ks, Vs, lens, *, sparse: bool):
    """One decode step over padded caches Ks/Vs (lists of (B,H,Smax,d)).

    Returns (next_ids (B,), new k/v per layer).  The caller appends k/v to
    the caches — mirroring the rust coordinator's KV manager.
    """
    x = embed_decode(ids, pos, params["tok_emb"], params["pos_emb"])
    new_kv = []
    for i in range(cfg.n_layers):
        w = layer_weights(params, i)
        q, k, v = qkv_proj(
            x, w["ln1_g"], w["ln1_b"], w["wq"], w["bq"], w["wk"], w["bk"],
            w["wv"], w["bv"], cfg=cfg,
        )
        # append k,v at position `lens` before attending (the new token
        # attends to itself, as in standard KV-cache decode)
        B = x.shape[0]
        idx = lens.astype(jnp.int32)
        K = Ks[i].at[jnp.arange(B), :, idx, :].set(k)
        V = Vs[i].at[jnp.arange(B), :, idx, :].set(v)
        Ks[i], Vs[i] = K, V
        attend = attn_sparf if sparse else attn_dense
        a = attend(q, K, V, lens + 1.0, cfg=cfg)
        x = post_attn(
            x, a, w["wo"], w["bo"], w["ln2_g"], w["ln2_b"], w["w1"], w["b1"],
            w["w2"], w["b2"], cfg=cfg,
        )
        new_kv.append((k, v))
    _, nxt = logits(x, params["ln_f_g"], params["ln_f_b"], params["tok_emb"])
    return nxt, new_kv
