"""Pure-jnp oracles for the attention kernels (the CORE correctness signal).

Every Pallas kernel in this package, every HLO artifact executed by the rust
runtime, and the rust-native CSD engine are all validated against the
functions in this module.

Shapes follow the paper's single-head decode-step convention
(Algorithm 1 of the InstInfer paper):

    q       : (d,)      current-token query vector for one head
    K, V    : (S, d)    per-head KV cache, padded to S rows
    length  : ()        number of valid rows in K/V (<= S)

Batched/multi-head variants are produced with `jax.vmap` by callers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def topk_mask(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask of the k largest entries of 1-D `x` (ties -> lower index).

    Implemented with a stable descending argsort instead of `lax.top_k`:
    the HLO `topk` op only exists in newer XLA and the AOT consumer
    (xla_extension 0.5.1, see aot.py) cannot parse it, while `sort` +
    scatter round-trip cleanly.  Semantics match `lax.top_k` (stable sort
    breaks ties by index).
    """
    order = jnp.argsort(-x, stable=True)
    return jnp.zeros(x.shape, bool).at[order[:k]].set(True)


def _valid_mask(S: int, length) -> jnp.ndarray:
    """Boolean (S,) mask of valid (non-padding) token rows."""
    return jnp.arange(S) < length


def masked_softmax(logits: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable softmax over `logits` restricted to `mask`.

    Entries where mask is False receive probability exactly 0.  If the mask
    is empty the result is all zeros (callers guarantee length >= 1).
    """
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m) * mask.astype(logits.dtype)
    z = jnp.sum(e, axis=-1, keepdims=True)
    return e / jnp.maximum(z, 1e-30)


def dense_attention(q: jnp.ndarray, K: jnp.ndarray, V: jnp.ndarray, length) -> jnp.ndarray:
    """Vanilla decode-phase attention for one head: softmax(qK^T/sqrt(d)) V."""
    S, d = K.shape
    mask = _valid_mask(S, length)
    logits = (K @ q) / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = masked_softmax(logits, mask)
    return s @ V


def v_mean(V: jnp.ndarray, length) -> jnp.ndarray:
    """Mean of the valid V rows — the compensation vector v̄ of Algorithm 1."""
    S = V.shape[0]
    mask = _valid_mask(S, length).astype(V.dtype)
    return (mask @ V) / jnp.maximum(jnp.sum(mask), 1.0)


def sparq_attention(
    q: jnp.ndarray,
    K: jnp.ndarray,
    V: jnp.ndarray,
    vbar: jnp.ndarray,
    length,
    *,
    r: int,
    k: int,
) -> jnp.ndarray:
    """Vanilla SparQ attention [Ribar et al.] — the baseline of Algorithm 1.

    Step A: approximate scores using only the top-r |q| embedding channels.
    Step B: exact attention over the top-k tokens of the approximate scores,
            blended with v̄ by the coverage weight alpha.
    """
    S, d = K.shape
    mask = _valid_mask(S, length)

    # -- step A: top-r embedding channels of |q|
    emb = topk_mask(jnp.abs(q), r)
    qr = jnp.where(emb, q, 0.0)
    # softmax temperature correction from the SparQ paper:
    # sqrt(d * |q_r|_1 / |q|_1)
    scale = jnp.sqrt(
        jnp.asarray(d, q.dtype)
        * jnp.sum(jnp.abs(qr))
        / jnp.maximum(jnp.sum(jnp.abs(q)), 1e-30)
    )
    s_hat = masked_softmax((K @ qr) / jnp.maximum(scale, 1e-30), mask)

    # -- step B: top-k tokens of the approximate scores
    tok = topk_mask(jnp.where(mask, s_hat, -1.0), k) & mask
    alpha = jnp.sum(jnp.where(tok, s_hat, 0.0))

    logits = (K @ q) / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = masked_softmax(logits, tok)
    return alpha * (s @ V) + (1.0 - alpha) * vbar


def sparf_token_groups(s_hat: jnp.ndarray, mask: jnp.ndarray, *, k: int, n: int):
    """Group-aligned top-k token selection (steps 5-9 of Algorithm 1).

    Returns (tok_mask, group_mask):
      tok_mask   (S,)    exact top-k tokens (what the NFC filter keeps)
      group_mask (S//n,) flash pages that must be fetched (a page is fetched
                         iff it contains at least one selected token)
    """
    S = s_hat.shape[0]
    tok = topk_mask(jnp.where(mask, s_hat, -1.0), k) & mask
    group = jnp.any(tok.reshape(S // n, n), axis=1)
    return tok, group


def sparf_embed_groups(q: jnp.ndarray, *, r: int, m: int):
    """Group-aligned top-r embedding selection (steps 1-3 of Algorithm 1).

    Returns (emb_mask, group_mask):
      emb_mask   (d,)    exact top-r channels (post-filter)
      group_mask (d//m,) embedding-indexed flash pages to fetch
    """
    d = q.shape[0]
    emb = topk_mask(jnp.abs(q), r)
    group = jnp.any(emb.reshape(d // m, m), axis=1)
    return emb, group


def sparf_attention(
    q: jnp.ndarray,
    K: jnp.ndarray,
    V: jnp.ndarray,
    vbar: jnp.ndarray,
    length,
    *,
    r: int,
    k: int,
    m: int,
    n: int,
) -> jnp.ndarray:
    """SparF attention — Algorithm 1 of the InstInfer paper.

    Functionally this equals SparQ with the same (r, k): the dual-step
    loading fetches whole flash pages (embedding groups of m channels,
    token groups of n tokens) but the NFC filter discards the weak units
    before any compute, so the arithmetic is identical.  The group
    structure is what the FTL and the bandwidth model consume; it is
    exposed separately via `sparf_stats`.
    """
    del m, n  # groups affect data movement, not the arithmetic
    return sparq_attention(q, K, V, vbar, length, r=r, k=k)


def sparf_stats(
    q: jnp.ndarray,
    K: jnp.ndarray,
    V: jnp.ndarray,
    length,
    *,
    r: int,
    k: int,
    m: int,
    n: int,
):
    """Data-movement statistics of one SparF step (for the bandwidth model).

    Returns a dict of scalar counts:
      emb_pages   embedding-indexed pages fetched in step 2
      tok_pages   token-indexed pages fetched in step 8 (x2: K and V)
      emb_kept    channels surviving the NFC filter (== r)
      tok_kept    tokens surviving the NFC filter  (== min(k, length))
    """
    S, d = K.shape
    mask = _valid_mask(S, length)
    emb, eg = sparf_embed_groups(q, r=r, m=m)
    qr = jnp.where(emb, q, 0.0)
    scale = jnp.sqrt(
        jnp.asarray(d, q.dtype)
        * jnp.sum(jnp.abs(qr))
        / jnp.maximum(jnp.sum(jnp.abs(q)), 1e-30)
    )
    s_hat = masked_softmax((K @ qr) / jnp.maximum(scale, 1e-30), mask)
    tok, tg = sparf_token_groups(s_hat, mask, k=k, n=n)
    return {
        "emb_pages": jnp.sum(eg.astype(jnp.int32)),
        "tok_pages": jnp.sum(tg.astype(jnp.int32)),
        "emb_kept": jnp.sum(emb.astype(jnp.int32)),
        "tok_kept": jnp.sum(tok.astype(jnp.int32)),
    }


def h2o_attention(
    q: jnp.ndarray,
    K: jnp.ndarray,
    V: jnp.ndarray,
    acc_scores: jnp.ndarray,
    length,
    *,
    k: int,
    window: int,
) -> jnp.ndarray:
    """H2O-style heavy-hitter attention (accuracy baseline for Fig. 11).

    Keeps the `window` most recent tokens plus the heaviest hitters by
    accumulated historical attention mass (`acc_scores`, maintained by the
    caller across decode steps), up to `k` tokens total.
    """
    S, d = K.shape
    mask = _valid_mask(S, length)
    recent = (jnp.arange(S) >= (length - window)) & mask
    heavy_pool = jnp.where(mask & ~recent, acc_scores, -1.0)
    n_heavy = max(k - window, 0)
    if n_heavy > 0:
        heavy = topk_mask(heavy_pool, n_heavy) & mask & ~recent
    else:
        heavy = jnp.zeros((S,), bool)
    keep = recent | heavy
    logits = (K @ q) / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = masked_softmax(logits, keep)
    return s @ V


def local_attention(
    q: jnp.ndarray,
    K: jnp.ndarray,
    V: jnp.ndarray,
    length,
    *,
    k: int,
) -> jnp.ndarray:
    """Sliding-window attention over the k most recent tokens (Fig. 11)."""
    S, d = K.shape
    mask = _valid_mask(S, length)
    keep = (jnp.arange(S) >= (length - k)) & mask
    logits = (K @ q) / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = masked_softmax(logits, keep)
    return s @ V


def causal_attention(Q: jnp.ndarray, K: jnp.ndarray, V: jnp.ndarray) -> jnp.ndarray:
    """Prefill-phase causal attention for one head: Q,K,V (S, d) -> (S, d)."""
    S, d = Q.shape
    logits = (Q @ K.T) / jnp.sqrt(jnp.asarray(d, Q.dtype))
    causal = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(causal, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m) * causal.astype(logits.dtype)
    s = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    return s @ V


# Convenience batched variants (B*H leading axis), used by the L2 model and
# by the golden-generation path in aot.py.
dense_attention_bh = jax.vmap(dense_attention, in_axes=(0, 0, 0, 0))
causal_attention_bh = jax.vmap(causal_attention, in_axes=(0, 0, 0))


def sparf_attention_bh(q, K, V, vbar, length, *, r, k, m, n):
    fn = functools.partial(sparf_attention, r=r, k=k, m=m, n=n)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0, 0))(q, K, V, vbar, length)
