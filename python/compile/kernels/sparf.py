"""Pallas kernel: SparF attention (Algorithm 1) — the InstCSD hot-spot.

One grid step per (batch x head) slot, executing the full dual-step SparF
pipeline exactly as the in-storage engine does:

  step 1    argtopk unit: top-r channels of |q|
  step 2-3  embedding-indexed page fetch + NFC filter (here: group-aligned
            load mask, then exact channel mask — the masked elements never
            contribute, mirroring the filter discarding weak units)
  step 4    Attention Kernel #1: approximate scores over masked channels
  step 5-6  argtopk unit: top-k tokens
  step 7    alpha = covered approximate mass
  step 8-9  token-indexed page fetch + NFC filter
  step 10   Attention Kernel #2: exact scores over kept tokens
  step 11   output blended with v̄ by alpha

TPU adaptation: gathers become mask-multiplies (dense-friendly on the MXU;
the savings appear in the HBM<->VMEM schedule, which on the CSD is the
flash-channel schedule).  interpret=True for CPU PJRT (see dense.py).

Shapes:
    q    (BH, d)
    K, V (BH, S, d)
    lens (BH,)  float32 valid lengths
    out  (BH, d)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _sparf_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, r: int, k: int, m: int, n: int):
    q = q_ref[0]                    # (d,)
    K = k_ref[0]                    # (S, d)
    V = v_ref[0]                    # (S, d)
    length = len_ref[0]
    S, d = K.shape
    fdtype = q.dtype
    valid = (jnp.arange(S).astype(length.dtype) < length)
    validf = valid.astype(fdtype)

    # v̄: compensation vector (paper computes it incrementally on writes;
    # functionally it is the mean of valid V rows).
    n_valid = jnp.maximum(jnp.sum(validf), 1.0)
    vbar = (validf @ V) / n_valid

    # ---- step 1: argtopk over |q| channels -------------------------------
    # top-k via stable descending argsort: the consumer XLA (0.5.1) cannot
    # parse the newer `topk` HLO op, while sort+scatter round-trip (ref.py
    # uses the identical construction, keeping kernel == oracle bit-exact).
    absq = jnp.abs(q)
    ei = jnp.argsort(-absq, stable=True)[:r]
    emb = jnp.zeros((d,), jnp.bool_).at[ei].set(True)

    # ---- steps 2-3: embedding-page load + NFC filter ---------------------
    # Page-level OR over groups of m channels decides which embedding-indexed
    # pages stream in; the filter then zeroes the weak channels.  In the
    # masked formulation only `emb` survives — the group mask is what the
    # FTL/bandwidth model charges for.
    emb_group = jnp.repeat(jnp.any(emb.reshape(d // m, m), axis=1), m)
    emb_eff = emb & emb_group       # == emb; keeps the dataflow explicit
    qr = jnp.where(emb_eff, q, 0.0)

    # ---- step 4: Attention Kernel #1 (approximate scores) ----------------
    scale_hat = jnp.sqrt(
        jnp.asarray(d, fdtype) * jnp.sum(jnp.abs(qr))
        / jnp.maximum(jnp.sum(absq), 1e-30)
    )
    logits_hat = jnp.where(valid, (K @ qr) / jnp.maximum(scale_hat, 1e-30), NEG_INF)
    mh = jnp.max(logits_hat)
    eh = jnp.exp(logits_hat - mh) * validf
    s_hat = eh / jnp.maximum(jnp.sum(eh), 1e-30)

    # ---- steps 5-6: argtopk over tokens ----------------------------------
    ti = jnp.argsort(-jnp.where(valid, s_hat, -1.0), stable=True)[:k]
    tok = jnp.zeros((S,), jnp.bool_).at[ti].set(True) & valid

    # ---- step 7: covered mass --------------------------------------------
    alpha = jnp.sum(jnp.where(tok, s_hat, 0.0))

    # ---- steps 8-9: token-page load + NFC filter -------------------------
    tok_group = jnp.repeat(jnp.any(tok.reshape(S // n, n), axis=1), n)
    tok_eff = tok & tok_group       # == tok

    # ---- step 10: Attention Kernel #2 (exact scores on kept tokens) ------
    logits = jnp.where(tok_eff, (K @ q) / jnp.sqrt(jnp.asarray(d, fdtype)), NEG_INF)
    mx = jnp.max(logits)
    ex = jnp.exp(logits - mx) * tok_eff.astype(fdtype)
    s = ex / jnp.maximum(jnp.sum(ex), 1e-30)

    # ---- step 11: blend with v̄ -------------------------------------------
    o_ref[0] = alpha * (s @ V) + (1.0 - alpha) * vbar


def sparf_decode_attention(
    q, K, V, lens, *, r: int, k: int, m: int, n: int, interpret: bool = True
):
    """SparF attention over (BH, S, d) KV caches; see module docstring."""
    BH, S, d = K.shape
    assert d % m == 0, f"d={d} must be a multiple of the embedding group {m}"
    assert S % n == 0, f"S={S} must be a multiple of the token group {n}"
    assert r <= d and k <= S
    kernel = functools.partial(_sparf_kernel, r=r, k=k, m=m, n=n)
    return pl.pallas_call(
        kernel,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, S, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, S, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, d), q.dtype),
        interpret=interpret,
    )(q, K, V, lens)
