"""Pallas kernel: dense decode-phase attention (the InstI-Dense engine).

One grid step per (batch x head).  The KV cache for the head is streamed
group-by-group (a "group" = one flash page worth of tokens, the same unit
the InstCSD NFC fetches) with an online-softmax accumulator, mirroring how
the in-storage attention engine consumes pages as they arrive from the
flash channels.

TPU adaptation (DESIGN.md §2): the flash page group maps to the block over
the sequence axis; the online-softmax carry lives in registers/VMEM.  The
kernel is lowered with interpret=True — CPU PJRT cannot execute Mosaic
custom-calls — and its VMEM/MXU characteristics are estimated statically
(EXPERIMENTS.md §Perf).

Shapes:
    q    (BH, d)        current-token queries, one row per (batch, head)
    K, V (BH, S, d)     padded KV cache
    lens (BH,)          float32 valid lengths
    out  (BH, d)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _dense_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, group: int):
    """One (batch, head) slot: online-softmax attention over page groups."""
    q = q_ref[0]                    # (d,)
    K = k_ref[0]                    # (S, d)
    V = v_ref[0]                    # (S, d)
    length = len_ref[0]
    S, d = K.shape
    n_groups = S // group
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))

    def body(g, carry):
        m_run, l_run, acc = carry
        kg = jax.lax.dynamic_slice(K, (g * group, 0), (group, d))
        vg = jax.lax.dynamic_slice(V, (g * group, 0), (group, d))
        idx = g * group + jnp.arange(group)
        valid = (idx.astype(length.dtype) < length)
        logits = jnp.where(valid, (kg @ q) * scale, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(logits))
        corr = jnp.exp(m_run - m_new)
        p = jnp.exp(logits - m_new) * valid.astype(q.dtype)
        l_new = l_run * corr + jnp.sum(p)
        acc_new = acc * corr + p @ vg
        return m_new, l_new, acc_new

    init = (jnp.asarray(NEG_INF, q.dtype), jnp.asarray(0.0, q.dtype), jnp.zeros((d,), q.dtype))
    _, l_fin, acc = jax.lax.fori_loop(0, n_groups, body, init)
    o_ref[0] = acc / jnp.maximum(l_fin, 1e-30)


def dense_decode_attention(q, K, V, lens, *, group: int = 16, interpret: bool = True):
    """softmax(q K^T / sqrt(d)) V per (batch, head) slot, page-streamed.

    `group` is the flash-page token group size (16 tokens for d_head=128
    FP16 on 4 KiB pages — paper §IV-C; scaled configs pass their own).
    """
    BH, S, d = K.shape
    assert S % group == 0, f"S={S} must be a multiple of the page group {group}"
    kernel = functools.partial(_dense_kernel, group=group)
    return pl.pallas_call(
        kernel,
        grid=(BH,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, S, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, S, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, d), q.dtype),
        interpret=interpret,
    )(q, K, V, lens)
