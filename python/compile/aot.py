"""AOT pipeline: lower the L2 operator groups to HLO text artifacts.

Runs ONCE at build time (`make artifacts`); python is never on the request
path.  Emits into --out-dir:

    <exe>__b<B>.hlo.txt   HLO text per executable per batch bucket
    manifest.json         model config + executable signatures + indices
    weights.bin           all parameters, raw little-endian float32
    golden.bin            input/output tensors for the rust golden tests

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

BATCH_BUCKETS = [1, 4, 8]
PREFILL_SEQ = 64  # baked prompt-chunk length; rust pads shorter prompts

F32, I32 = "f32", "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _np_dtype(d: str):
    return np.int32 if d == I32 else np.float32


class ArgSpec:
    """One positional argument of an executable.

    kind   'input' (runtime tensor) or 'weight' (bound from weights.bin)
    scope  for weights: 'global' (bind by name) or 'layer' (bind
           'layers.{i}.<name>')
    shape  may contain the symbol 'B' (batch bucket) as a string entry.
    """

    def __init__(self, name, kind, shape, dtype=F32, scope="global"):
        self.name, self.kind, self.shape, self.dtype, self.scope = (
            name, kind, shape, dtype, scope)

    def concrete(self, B: int) -> Tuple[int, ...]:
        return tuple(B if s == "B" else s for s in self.shape)

    def manifest(self) -> dict:
        return {
            "name": self.name, "kind": self.kind, "scope": self.scope,
            "shape": list(self.shape), "dtype": self.dtype,
        }


def inp(name, shape, dtype=F32):
    return ArgSpec(name, "input", shape, dtype)


def wgt(name, shape, scope="layer"):
    return ArgSpec(name, "weight", shape, F32, scope)


def registry(cfg: M.ModelConfig) -> Dict[str, Tuple[Callable, List[ArgSpec]]]:
    """Executable name -> (fn, arg specs in positional order)."""
    D, H, dh, F, S, V = (cfg.d_model, cfg.n_heads, cfg.d_head, cfg.d_ffn,
                         cfg.max_seq, cfg.vocab)
    SP = PREFILL_SEQ
    ls = M.layer_slot_shapes(cfg)

    def lw(*slots):
        return [wgt(s, ls[s]) for s in slots]

    qkv = functools.partial(M.qkv_proj, cfg=cfg)
    adense = functools.partial(M.attn_dense, cfg=cfg)
    asparf = functools.partial(M.attn_sparf, cfg=cfg)
    pattn = functools.partial(M.post_attn, cfg=cfg)
    pblock = functools.partial(M.prefill_block, cfg=cfg)

    return {
        "embed_decode": (
            M.embed_decode,
            [inp("ids", ("B",), I32), inp("pos", ("B",), I32),
             wgt("tok_emb", (V, D), "global"), wgt("pos_emb", (S, D), "global")],
        ),
        "qkv_proj": (
            qkv,
            [inp("x", ("B", D))] + lw("ln1_g", "ln1_b", "wq", "bq", "wk", "bk",
                                      "wv", "bv"),
        ),
        "attn_dense": (
            adense,
            [inp("q", ("B", H, dh)), inp("K", ("B", H, S, dh)),
             inp("V", ("B", H, S, dh)), inp("lens", ("B",))],
        ),
        "attn_sparf": (
            asparf,
            [inp("q", ("B", H, dh)), inp("K", ("B", H, S, dh)),
             inp("V", ("B", H, S, dh)), inp("lens", ("B",))],
        ),
        "post_attn": (
            pattn,
            [inp("x", ("B", D)), inp("attn", ("B", H, dh))]
            + lw("wo", "bo", "ln2_g", "ln2_b", "w1", "b1", "w2", "b2"),
        ),
        "logits": (
            M.logits,
            [inp("x", ("B", D)), wgt("ln_f_g", (D,), "global"),
             wgt("ln_f_b", (D,), "global"), wgt("tok_emb", (V, D), "global")],
        ),
        "embed_prefill": (
            M.embed_prefill,
            [inp("ids", ("B", SP), I32), wgt("tok_emb", (V, D), "global"),
             wgt("pos_emb", (S, D), "global")],
        ),
        "prefill_block": (
            pblock,
            [inp("x", ("B", SP, D))] + lw(*M.LAYER_SLOTS),
        ),
    }


def golden_inputs(name: str, specs: List[ArgSpec], B: int, cfg: M.ModelConfig):
    """Deterministic non-weight inputs for the golden record."""
    rng = np.random.default_rng(abs(hash(name)) % (2**31))
    out = []
    for s in specs:
        if s.kind != "input":
            continue
        shape = s.concrete(B)
        if s.dtype == I32:
            hi = cfg.vocab if s.name == "ids" else cfg.max_seq
            arr = rng.integers(0, hi, shape, dtype=np.int32)
        elif s.name == "lens":
            arr = rng.integers(1, cfg.max_seq, shape).astype(np.float32)
        else:
            arr = rng.standard_normal(shape).astype(np.float32)
        out.append((s.name, arr))
    return out


def flatten_outputs(res) -> List[np.ndarray]:
    leaves = jax.tree_util.tree_leaves(res)
    return [np.asarray(x) for x in leaves]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", required=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = M.SMALL
    params = M.init_params(cfg, seed=args.seed)
    reg = registry(cfg)

    manifest: dict = {
        "model": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "d_head": cfg.d_head, "d_ffn": cfg.d_ffn,
            "n_layers": cfg.n_layers, "max_seq": cfg.max_seq,
            "prefill_seq": PREFILL_SEQ,
            "r": cfg.r, "k": cfg.k, "m": cfg.m, "n": cfg.n,
        },
        "batch_buckets": BATCH_BUCKETS,
        "executables": {},
        "weights": {},
        "golden": {},
    }

    # ---- weights.bin ------------------------------------------------------
    woff = 0
    with open(os.path.join(args.out_dir, "weights.bin"), "wb") as wf:
        for name in sorted(params):
            arr = np.asarray(params[name], np.float32)
            manifest["weights"][name] = {
                "offset": woff, "shape": list(arr.shape), "dtype": F32,
            }
            wf.write(arr.tobytes())
            woff += arr.nbytes
    manifest["weights_bytes"] = woff

    # ---- HLO artifacts ----------------------------------------------------
    for name, (fn, specs) in reg.items():
        files = {}
        for B in BATCH_BUCKETS:
            shapes = [
                jax.ShapeDtypeStruct(s.concrete(B), _np_dtype(s.dtype))
                for s in specs
            ]
            lowered = jax.jit(fn).lower(*shapes)
            fname = f"{name}__b{B}.hlo.txt"
            with open(os.path.join(args.out_dir, fname), "w") as f:
                f.write(to_hlo_text(lowered))
            outs = jax.eval_shape(fn, *shapes)
            files[str(B)] = {
                "file": fname,
                "outputs": [
                    {"shape": list(o.shape),
                     "dtype": I32 if np.issubdtype(o.dtype, np.integer) else F32}
                    for o in jax.tree_util.tree_leaves(outs)
                ],
            }
            print(f"lowered {fname}")
        manifest["executables"][name] = {
            "args": [s.manifest() for s in specs],
            "buckets": files,
        }

    # ---- golden.bin (B=1, layer-0 weights) --------------------------------
    goff = 0
    with open(os.path.join(args.out_dir, "golden.bin"), "wb") as gf:

        def emit(arr: np.ndarray) -> dict:
            nonlocal goff
            arr = np.ascontiguousarray(arr)
            rec = {
                "offset": goff, "shape": list(arr.shape),
                "dtype": I32 if arr.dtype == np.int32 else F32,
            }
            gf.write(arr.tobytes())
            goff += arr.nbytes
            return rec

        for name, (fn, specs) in reg.items():
            B = 1
            gin = dict(golden_inputs(name, specs, B, cfg))
            call_args, in_recs = [], []
            for s in specs:
                if s.kind == "input":
                    arr = gin[s.name]
                    r = emit(arr)
                    r["name"] = s.name
                    in_recs.append(r)
                    call_args.append(jnp.asarray(arr))
                else:
                    pname = s.name if s.scope == "global" else f"layers.0.{s.name}"
                    call_args.append(params[pname])
            res = jax.jit(fn)(*call_args)
            out_recs = []
            for arr in flatten_outputs(res):
                r = emit(arr.astype(np.int32 if arr.dtype == np.int32 else np.float32))
                out_recs.append(r)
            manifest["golden"][name] = {
                "batch": B, "layer": 0, "inputs": in_recs, "outputs": out_recs,
            }
            print(f"golden {name}: {len(in_recs)} in / {len(out_recs)} out")

    manifest["golden_bytes"] = goff
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest written; weights={woff}B golden={goff}B")


if __name__ == "__main__":
    main()
