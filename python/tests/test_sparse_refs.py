"""Properties of the sparse-attention reference family (Fig. 11 methods)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref

SET = dict(deadline=None, max_examples=20)


def mk1(rng, S, d):
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    K = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
    V = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
    return q, K, V


def test_masked_softmax_sums_to_one_on_mask():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(64), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, 64), bool).at[0].set(True)
    s = ref.masked_softmax(x, mask)
    assert_allclose(float(jnp.sum(s)), 1.0, rtol=1e-5)
    assert float(jnp.max(jnp.where(mask, 0.0, s))) == 0.0


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1), S=st.sampled_from([32, 64]),
       d=st.sampled_from([16, 32]))
def test_sparq_full_budget_equals_dense(seed, S, d):
    rng = np.random.default_rng(seed)
    q, K, V = mk1(rng, S, d)
    vbar = ref.v_mean(V, float(S))
    out = ref.sparq_attention(q, K, V, vbar, float(S), r=d, k=S)
    want = ref.dense_attention(q, K, V, float(S))
    assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_sparf_equals_sparq_functionally(seed):
    """Group alignment moves pages, not arithmetic (paper: 'nearly identical
    accuracy' because the filter discards weak units before compute)."""
    rng = np.random.default_rng(seed)
    q, K, V = mk1(rng, 64, 32)
    vbar = ref.v_mean(V, 50.0)
    a = ref.sparf_attention(q, K, V, vbar, 50.0, r=8, k=8, m=4, n=8)
    b = ref.sparq_attention(q, K, V, vbar, 50.0, r=8, k=8)
    assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)


def test_local_attention_equals_dense_on_short_sequence():
    rng = np.random.default_rng(1)
    q, K, V = mk1(rng, 64, 16)
    # only 10 valid tokens, window of 16 covers everything
    out = ref.local_attention(q, K, V, 10.0, k=16)
    want = ref.dense_attention(q, K, V, 10.0)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_h2o_keeps_recent_window():
    """With a huge recent token, H2O (which always keeps the window) must
    match dense closely, while pure heavy-hitter selection could miss it."""
    rng = np.random.default_rng(2)
    q, K, V = mk1(rng, 64, 16)
    K = K.at[49].set(q * 10.0)  # token 49 (recent) dominates attention
    acc = jnp.asarray(rng.random(64), jnp.float32)
    out = ref.h2o_attention(q, K, V, acc, 50.0, k=16, window=8)
    want = ref.dense_attention(q, K, V, 50.0)
    assert float(jnp.max(jnp.abs(out - want))) < 0.15


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_alpha_blend_is_convex(seed):
    """SparF output lies in the convex hull sense: alpha in [0,1]."""
    rng = np.random.default_rng(seed)
    q, K, V = mk1(rng, 64, 32)
    mask = ref._valid_mask(64, 40.0)
    emb, _ = ref.sparf_embed_groups(q, r=8, m=4)
    qr = jnp.where(emb, q, 0.0)
    scale = jnp.sqrt(32.0 * jnp.sum(jnp.abs(qr)) / jnp.sum(jnp.abs(q)))
    s_hat = ref.masked_softmax((K @ qr) / scale, mask)
    tok, _ = ref.sparf_token_groups(s_hat, mask, k=8, n=8)
    alpha = float(jnp.sum(jnp.where(tok, s_hat, 0.0)))
    assert 0.0 <= alpha <= 1.0 + 1e-6


def test_causal_attention_last_row_equals_decode():
    """Row t of causal prefill == decode attention with length t+1 — the
    invariant the coordinator relies on when switching phases."""
    rng = np.random.default_rng(3)
    S, d = 32, 16
    Q = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
    V = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
    full = ref.causal_attention(Q, K, V)
    for t in [0, 1, 7, 31]:
        dec = ref.dense_attention(Q[t], K, V, float(t + 1))
        assert_allclose(np.asarray(full[t]), np.asarray(dec), rtol=2e-5, atol=2e-5)


def test_vbar_ignores_padding():
    rng = np.random.default_rng(4)
    V = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    vb = ref.v_mean(V, 5.0)
    assert_allclose(np.asarray(vb), np.asarray(jnp.mean(V[:5], axis=0)),
                    rtol=1e-6, atol=1e-6)
