"""L2 correctness: operator groups compose into a consistent transformer."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model as M
from compile.kernels import ref

CFG = M.ModelConfig(
    name="test-nano", vocab=64, d_model=32, n_heads=4, d_head=8,
    d_ffn=64, n_layers=2, max_seq=32, r=4, k=8, m=4, n=8,
)


def test_init_params_shapes_and_determinism():
    p1 = M.init_params(CFG, seed=0)
    p2 = M.init_params(CFG, seed=0)
    p3 = M.init_params(CFG, seed=1)
    assert set(p1) == set(p2)
    for k in p1:
        assert p1[k].shape == p2[k].shape
        assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]))
    assert float(jnp.max(jnp.abs(p1["tok_emb"] - p3["tok_emb"]))) > 0


def test_layer_norm_moments():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 32)) * 3 + 1, jnp.float32)
    y = M.layer_norm(x, jnp.ones(32), jnp.zeros(32))
    assert_allclose(np.asarray(jnp.mean(y, -1)), np.zeros(4), atol=1e-5)
    assert_allclose(np.asarray(jnp.var(y, -1)), np.ones(4), rtol=1e-3)


def test_qkv_proj_matches_direct():
    p = M.init_params(CFG, seed=0)
    w = M.layer_weights(p, 0)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((3, CFG.d_model)), jnp.float32)
    q, k, v = M.qkv_proj(x, w["ln1_g"], w["ln1_b"], w["wq"], w["bq"],
                         w["wk"], w["bk"], w["wv"], w["bv"], cfg=CFG)
    h = M.layer_norm(x, w["ln1_g"], w["ln1_b"])
    assert_allclose(np.asarray(q.reshape(3, -1)), np.asarray(h @ w["wq"] + w["bq"]),
                    rtol=2e-5, atol=2e-5)
    assert q.shape == (3, CFG.n_heads, CFG.d_head)
    assert k.shape == v.shape == q.shape


def test_prefill_then_decode_consistency():
    """Decode step t over prefill caches == causal attention row t.

    This is the invariant the whole system rests on: the GPU prefill
    artifact's KV output, shipped to the CSD, must let the decode artifacts
    continue the sequence exactly.
    """
    p = M.init_params(CFG, seed=0)
    rng = np.random.default_rng(2)
    B, S = 2, 16
    ids = jnp.asarray(rng.integers(0, CFG.vocab, (B, S)), jnp.int32)

    # full causal pass over S+1 tokens = ground truth
    nxt_id = jnp.asarray(rng.integers(0, CFG.vocab, (B,)), jnp.int32)
    ids_full = jnp.concatenate([ids, nxt_id[:, None]], axis=1)
    x_full, _, _ = M.reference_prefill(p, CFG, ids_full)
    lg_full, _ = M.logits(x_full[:, -1], p["ln_f_g"], p["ln_f_b"], p["tok_emb"])

    # prefill S tokens, then one dense decode step for token S
    _, Ks, Vs = M.reference_prefill(p, CFG, ids)
    Smax = CFG.max_seq
    Ks = [jnp.pad(K, ((0, 0), (0, 0), (0, Smax - S), (0, 0))) for K in Ks]
    Vs = [jnp.pad(V, ((0, 0), (0, 0), (0, Smax - S), (0, 0))) for V in Vs]
    lens = jnp.full((B,), float(S), jnp.float32)
    pos = jnp.full((B,), S, jnp.int32)

    x = M.embed_decode(nxt_id, pos, p["tok_emb"], p["pos_emb"])
    for i in range(CFG.n_layers):
        w = M.layer_weights(p, i)
        q, k, v = M.qkv_proj(x, w["ln1_g"], w["ln1_b"], w["wq"], w["bq"],
                             w["wk"], w["bk"], w["wv"], w["bv"], cfg=CFG)
        K = Ks[i].at[:, :, S, :].set(k)
        V = Vs[i].at[:, :, S, :].set(v)
        a = M.attn_dense(q, K, V, lens + 1.0, cfg=CFG)
        x = M.post_attn(x, a, w["wo"], w["bo"], w["ln2_g"], w["ln2_b"],
                        w["w1"], w["b1"], w["w2"], w["b2"], cfg=CFG)
    lg_dec, _ = M.logits(x, p["ln_f_g"], p["ln_f_b"], p["tok_emb"])
    assert_allclose(np.asarray(lg_dec), np.asarray(lg_full), rtol=5e-4, atol=5e-4)


def test_reference_decode_step_greedy_loop_runs():
    """A short greedy generation loop is finite, deterministic, in-vocab."""
    p = M.init_params(CFG, seed=0)
    rng = np.random.default_rng(3)
    B, S = 2, 8
    ids = jnp.asarray(rng.integers(0, CFG.vocab, (B, S)), jnp.int32)
    _, Ks, Vs = M.reference_prefill(p, CFG, ids)
    Smax = CFG.max_seq
    Ks = [jnp.pad(K, ((0, 0), (0, 0), (0, Smax - S), (0, 0))) for K in Ks]
    Vs = [jnp.pad(V, ((0, 0), (0, 0), (0, Smax - S), (0, 0))) for V in Vs]

    cur = ids[:, -1]
    toks = []
    for t in range(4):
        lens = jnp.full((B,), float(S + t), jnp.float32)
        pos = jnp.full((B,), S + t, jnp.int32)
        cur, _ = M.reference_decode_step(p, CFG, cur, pos, Ks, Vs, lens,
                                         sparse=(t % 2 == 1))
        toks.append(np.asarray(cur))
    toks = np.stack(toks)
    assert toks.shape == (4, B)
    assert (toks >= 0).all() and (toks < CFG.vocab).all()


def test_sparse_decode_close_to_dense_decode():
    """SparF decode logits track dense decode logits (accuracy premise)."""
    p = M.init_params(CFG, seed=0)
    rng = np.random.default_rng(4)
    B, S = 2, 24
    ids = jnp.asarray(rng.integers(0, CFG.vocab, (B, S)), jnp.int32)
    _, Ks, Vs = M.reference_prefill(p, CFG, ids)
    Smax = CFG.max_seq
    Ks = [jnp.pad(K, ((0, 0), (0, 0), (0, Smax - S), (0, 0))) for K in Ks]
    Vs = [jnp.pad(V, ((0, 0), (0, 0), (0, Smax - S), (0, 0))) for V in Vs]
    lens = jnp.full((B,), float(S), jnp.float32)
    pos = jnp.full((B,), S, jnp.int32)
    cur = ids[:, -1]

    import copy
    n1, _ = M.reference_decode_step(p, CFG, cur, pos, [k for k in Ks], [v for v in Vs],
                                    lens, sparse=False)
    n2, _ = M.reference_decode_step(p, CFG, cur, pos, [k for k in Ks], [v for v in Vs],
                                    lens, sparse=True)
    # greedy tokens usually agree at this scale; require at least one match
    assert (np.asarray(n1) == np.asarray(n2)).sum() >= 1


def test_prefill_block_kv_layout():
    p = M.init_params(CFG, seed=0)
    w = M.layer_weights(p, 1)
    rng = np.random.default_rng(5)
    B, S = 2, 16
    x = jnp.asarray(rng.standard_normal((B, S, CFG.d_model)), jnp.float32)
    y, K, V = M.prefill_block(x, *[w[s] for s in M.LAYER_SLOTS], cfg=CFG)
    assert y.shape == (B, S, CFG.d_model)
    assert K.shape == V.shape == (B, CFG.n_heads, S, CFG.d_head)
    # K row t must equal the k-projection of LN(x[t])
    h = M.layer_norm(x, w["ln1_g"], w["ln1_b"])
    k_direct = (h @ w["wk"] + w["bk"]).reshape(B, S, CFG.n_heads, CFG.d_head)
    assert_allclose(np.asarray(K.transpose(0, 2, 1, 3)), np.asarray(k_direct),
                    rtol=2e-5, atol=2e-5)
