"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/seeds; every comparison is assert_allclose against
the reference — this is the core correctness signal for the kernels that the
AOT artifacts embed.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import dense, ref, sparf

SET = dict(deadline=None, max_examples=15)


def mk(rng, BH, S, d):
    q = jnp.asarray(rng.standard_normal((BH, d)), jnp.float32)
    K = jnp.asarray(rng.standard_normal((BH, S, d)), jnp.float32)
    V = jnp.asarray(rng.standard_normal((BH, S, d)), jnp.float32)
    lens = jnp.asarray(rng.integers(1, S + 1, BH), jnp.float32)
    return q, K, V, lens


@settings(**SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    BH=st.integers(1, 8),
    S=st.sampled_from([16, 32, 64, 128]),
    d=st.sampled_from([8, 16, 32]),
    group=st.sampled_from([4, 8, 16]),
)
def test_dense_kernel_matches_ref(seed, BH, S, d, group):
    rng = np.random.default_rng(seed)
    q, K, V, lens = mk(rng, BH, S, d)
    out = dense.dense_decode_attention(q, K, V, lens, group=group)
    want = ref.dense_attention_bh(q, K, V, lens)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(**SET)
@given(
    seed=st.integers(0, 2**31 - 1),
    BH=st.integers(1, 6),
    S=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([16, 32]),
)
def test_sparf_kernel_matches_ref(seed, BH, S, d):
    rng = np.random.default_rng(seed)
    q, K, V, lens = mk(rng, BH, S, d)
    r, k, m, n = d // 4, S // 8, 4, 8
    out = sparf.sparf_decode_attention(q, K, V, lens, r=r, k=k, m=m, n=n)
    vbar = jax.vmap(ref.v_mean)(V, lens)
    want = ref.sparf_attention_bh(q, K, V, vbar, lens, r=r, k=k, m=m, n=n)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_dense_kernel_full_length_equals_plain_softmax():
    rng = np.random.default_rng(7)
    BH, S, d = 4, 32, 16
    q, K, V, _ = mk(rng, BH, S, d)
    lens = jnp.full((BH,), float(S), jnp.float32)
    out = dense.dense_decode_attention(q, K, V, lens, group=8)
    logits = jnp.einsum("bsd,bd->bs", K, q) / jnp.sqrt(float(d))
    want = jnp.einsum("bs,bsd->bd", jax.nn.softmax(logits, axis=-1), V)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_dense_kernel_ignores_padding_rows():
    """Garbage in padded K/V rows must not change the output."""
    rng = np.random.default_rng(11)
    BH, S, d = 3, 64, 16
    q, K, V, _ = mk(rng, BH, S, d)
    lens = jnp.asarray([5.0, 17.0, 64.0], jnp.float32)
    out1 = dense.dense_decode_attention(q, K, V, lens, group=8)
    K2 = K.at[:, 40:, :].set(1e6)  # poison rows beyond length (head 0/1)
    V2 = V.at[:, 40:, :].set(-1e6)
    K2 = K2.at[2].set(K[2])  # head 2 uses full length; keep it intact
    V2 = V2.at[2].set(V[2])
    out2 = dense.dense_decode_attention(q, K2, V2, lens, group=8)
    assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


def test_sparf_kernel_ignores_padding_rows():
    rng = np.random.default_rng(13)
    BH, S, d = 2, 64, 32
    q, K, V, _ = mk(rng, BH, S, d)
    lens = jnp.asarray([9.0, 33.0], jnp.float32)
    args = dict(r=8, k=8, m=4, n=8)
    out1 = sparf.sparf_decode_attention(q, K, V, lens, **args)
    K2 = K.at[:, 48:, :].set(1e6)
    V2 = V.at[:, 48:, :].set(-1e6)
    out2 = sparf.sparf_decode_attention(q, K2, V2, lens, **args)
    assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6, atol=1e-6)


def test_sparf_full_rank_recovers_alpha_weighted_dense():
    """With r=d and k=S (no sparsity) alpha -> 1 and SparF == dense."""
    rng = np.random.default_rng(3)
    BH, S, d = 4, 32, 16
    q, K, V, _ = mk(rng, BH, S, d)
    lens = jnp.full((BH,), float(S), jnp.float32)
    out = sparf.sparf_decode_attention(q, K, V, lens, r=d, k=S, m=4, n=8)
    want = ref.dense_attention_bh(q, K, V, lens)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(**SET)
@given(seed=st.integers(0, 2**31 - 1))
def test_sparf_error_decreases_with_budget(seed):
    """More budget (r, k) must not make the approximation much worse.

    Property is statistical per-head, so compare mean absolute error over a
    moderate batch.
    """
    rng = np.random.default_rng(seed)
    BH, S, d = 8, 128, 32
    q, K, V, lens = mk(rng, BH, S, d)
    lens = jnp.full((BH,), float(S), jnp.float32)
    want = ref.dense_attention_bh(q, K, V, lens)

    def err(r, k):
        out = sparf.sparf_decode_attention(q, K, V, lens, r=r, k=k, m=4, n=8)
        return float(jnp.mean(jnp.abs(out - want)))

    lo = err(4, 8)
    hi = err(16, 64)
    assert hi <= lo * 1.05 + 1e-6


def test_sparf_stats_page_bounds():
    """Dual-step loading: fetched pages bounded by ceil-division of budget."""
    rng = np.random.default_rng(5)
    S, d, r, k, m, n = 128, 32, 8, 16, 4, 8
    for _ in range(20):
        q = jnp.asarray(rng.standard_normal(d), jnp.float32)
        K = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
        V = jnp.asarray(rng.standard_normal((S, d)), jnp.float32)
        stats = ref.sparf_stats(q, K, V, float(S), r=r, k=k, m=m, n=n)
        assert int(stats["emb_kept"]) == r
        assert int(stats["tok_kept"]) == k
        # at most one page per selected unit, at least ceil(selected/group)
        assert (r + m - 1) // m <= int(stats["emb_pages"]) <= r
        assert (k + n - 1) // n <= int(stats["tok_pages"]) <= k
