"""AOT registry sanity: signatures, bucket substitution, golden determinism."""

import numpy as np

from compile import aot
from compile import model as M


def test_registry_covers_decode_and_prefill_paths():
    reg = aot.registry(M.SMALL)
    assert {"embed_decode", "qkv_proj", "attn_dense", "attn_sparf",
            "post_attn", "logits", "embed_prefill", "prefill_block"} <= set(reg)


def test_argspec_bucket_substitution():
    s = aot.ArgSpec("K", "input", ("B", 8, 128, 32))
    assert s.concrete(4) == (4, 8, 128, 32)
    assert s.concrete(1) == (1, 8, 128, 32)
    m = s.manifest()
    assert m["shape"][0] == "B" and m["kind"] == "input"


def test_weight_specs_resolve_against_params():
    cfg = M.SMALL
    params = M.init_params(cfg, seed=0)
    for name, (_, specs) in aot.registry(cfg).items():
        for s in specs:
            if s.kind != "weight":
                continue
            pname = s.name if s.scope == "global" else f"layers.0.{s.name}"
            assert pname in params, f"{name}: missing weight {pname}"
            assert tuple(params[pname].shape) == s.concrete(1), (
                f"{name}.{s.name}: manifest {s.shape} vs param "
                f"{params[pname].shape}")


def test_golden_inputs_deterministic_and_typed():
    cfg = M.SMALL
    reg = aot.registry(cfg)
    for name, (_, specs) in reg.items():
        a = dict(aot.golden_inputs(name, specs, 1, cfg))
        b = dict(aot.golden_inputs(name, specs, 1, cfg))
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])
            assert a[k].dtype in (np.float32, np.int32)
        if "ids" in a:
            assert a["ids"].max() < cfg.vocab
        if "lens" in a:
            assert 1 <= a["lens"].min() and a["lens"].max() < cfg.max_seq


def test_layer_slots_complete():
    shapes = M.layer_slot_shapes(M.SMALL)
    assert set(M.LAYER_SLOTS) == set(shapes)
